"""End-to-end training driver: train the ~125M xlstm-125m (or any --arch at
full or --smoke scale) with checkpointing + fault tolerance.

CPU demo (a few minutes):
  PYTHONPATH=src python examples/train_lm.py --steps 200 --batch 4 --seq 128

Full 125M run (the assigned config, sized for a real accelerator):
  PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse

from repro.models.registry import get_config
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--full", action="store_true",
                    help="full published config (default: reduced smoke)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    tcfg = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                       lr=args.lr, ckpt_dir=args.ckpt_dir, ckpt_every=100,
                       log_every=10)
    trainer = Trainer(cfg, tcfg)
    trainer.run()
    log = trainer.metrics_log
    print(f"\n{'step':>6s} {'loss':>9s} {'ms/step':>8s}")
    for m in log:
        print(f"{m['step']:6d} {m['loss']:9.4f} {m['dt']*1e3:8.0f}")
    print(f"\nloss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f} over "
          f"{args.steps} steps; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
