"""Trace-driven serving demo (DESIGN.md §13): generate a Zipf-skewed,
bursty request trace, save/reload it to show the provenance round-trip,
then replay the SAME trace through the kv_serving workload under each
protocol scenario and compare makespan + per-request latency tails.

  PYTHONPATH=src python examples/kv_serving_demo.py [--agents 16]
      [--requests 64] [--zipf 1.2] [--burstiness 4.0] [--seed 0]
      [--engine fused] [--scenarios srsp rsp baseline]

The trace is bitwise-replayable from (seed, config) — every scenario
below serves the identical request stream, so the latency differences
are purely the protocol's.  `scope_only` is excluded by default: it
fails its self-check by design (the staleness demo).
"""
import argparse
import dataclasses
import tempfile

import numpy as np

from repro import workloads
from repro.traffic import trace as TR
from repro.traffic.samplers import TrafficConfig
from repro.workloads import harness


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=16)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--zipf", type=float, default=1.2)
    ap.add_argument("--burstiness", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="fused",
                    choices=sorted(harness.engines()))
    ap.add_argument("--scenarios", nargs="+",
                    default=["srsp", "rsp", "baseline"])
    args = ap.parse_args()

    cfg = TrafficConfig(requests_per_agent=args.requests, zipf_s=args.zipf,
                        gap_mean=8.0, burstiness=args.burstiness,
                        remote_frac=0.125)
    mod = workloads.get("kv_serving")
    wl_probe = mod.build("srsp", args.agents, seed=args.seed,
                         traffic=cfg).wl
    n_keys = wl_probe.cfg.n_pages

    tr = TR.generate(cfg, args.agents, n_keys, args.seed)
    with tempfile.NamedTemporaryFile(suffix=".npz") as f:
        TR.save(f.name, tr, cfg=cfg, n_agents=args.agents, n_keys=n_keys,
                seed=args.seed)
        tr2, meta = TR.load(f.name)
    assert meta["config"] == cfg
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(tr, tr2))
    owner = np.asarray(TR.owner(tr.key, args.agents))
    remote = float(np.mean(owner != np.asarray(tr.agent)))
    print(f"trace: {len(np.asarray(tr.key))} requests, {args.agents} agents,"
          f" {n_keys} keys, zipf_s={args.zipf}, burstiness={args.burstiness}"
          f" ({remote:.0%} cross-shard) — .npz round-trip bitwise OK")
    hot = np.bincount(np.asarray(tr.key), minlength=n_keys)
    print(f"hottest key serves {hot.max()}x, median key {int(np.median(hot))}x"
          f" (skew the asymmetric-sharing claim lives on)\n")

    print(f"{'scenario':<12} {'makespan':>10} {'completed':>10} "
          f"{'p50':>8} {'p95':>8} {'p99':>8}  check")
    rows = {}
    for scen in args.scenarios:
        b = mod.build(scen, args.agents, seed=args.seed, traffic=cfg)
        final = harness.runner(args.engine)(b.wl, b.state, *b.ops)
        res = b.check(final)
        lat = res["latency"]
        mk = float(np.max(np.asarray(final.store.counters.cycles)))
        rows[scen] = (mk, lat)
        print(f"{scen:<12} {mk:>10.0f} "
              f"{res['completed']:>6}/{res['offered']:<4}"
              f"{lat['p50']:>8.0f} {lat['p95']:>8.0f} {lat['p99']:>8.0f}  "
              f"{'OK' if res['ok'] else 'FAIL'}")

    if "srsp" in rows:
        mk_s, lat_s = rows["srsp"]
        for scen, (mk, lat) in rows.items():
            if scen == "srsp":
                continue
            print(f"\nsrsp vs {scen}: makespan x{mk / mk_s:.2f}, "
                  f"p99 x{lat['p99'] / max(lat_s['p99'], 1.0):.2f} "
                  f"(>1.0 means srsp wins)")


if __name__ == "__main__":
    main()
