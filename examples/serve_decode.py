"""Serving example: batched requests through prefill + greedy decode with
KV caches (the decode path the decode_32k / long_500k dry-run cells lower).

  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b
"""
import argparse

import numpy as np

from repro.models.registry import build, get_config
from repro.serve.engine import Engine, Request, throughput_bench

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    engine = Engine(model, params, max_len=128)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, rng.integers(8, 24))
                    .astype(np.int32), max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    done = engine.generate(reqs)
    for i, r in enumerate(done):
        print(f"req{i}: prompt[{len(r.prompt)}] -> generated {list(r.out)}")

    print("\nbatched throughput (smoke config, CPU):")
    stats = throughput_bench(model, params, batch=4, seq=64, new_tokens=8)
    for k, v in stats.items():
        print(f"  {k}: {v:.4f}")


if __name__ == "__main__":
    main()
