"""Elastic alive-set scheduling demo (DESIGN.md §10, ISSUE 6).

Three runs of the work-stealing bench on the batched ELASTIC engine:

  1. zero churn        — the elastic wrapper is bitwise invisible;
  2. crash + recovery  — agent 0 (owner of most chunks) dies INSIDE a
     critical section (faults.crash_holding_lock): its release never
     executes, so the queue lock stays held and its lease survives.  A
     CRASH churn event retires the agent; when the lease expires the
     protocol runs a recovery drain — write back the dead agent's dirty
     words, force-release its leased sync word, invalidate its
     LR/PA-TBL entries — and the surviving thieves drain its queue.
  3. crash, no recovery (faults.lease_never_expires) — the pre-lease
     wedge: the run still TERMINATES (elastic loop guard) but the
     self-check reports the chunks lost behind the dead agent's lock.

Then a leave→join round on kv_directory: a LEAVE retires an agent (its
obligations are forgiven, its state reclaimed immediately), a later
JOIN re-admits it with fresh work.

  PYTHONPATH=src python examples/elastic_churn_demo.py [--trace]

With --trace each run also records the in-engine event ring
(DESIGN.md §11) and the crash+recovery run is exported to
TRACE_churn_demo.json — load it at https://ui.perfetto.dev to see the
crash instant, the recovery drain, and the thieves' steal traffic on
per-agent tracks (`python -m repro.obs.report --demo` is the
one-command equivalent).
"""
import sys

import numpy as np

from repro import workloads
from repro.core import protocol as P
from repro.obs import export, trace as T
from repro.workloads import faults, harness

# the pinned crash geometry from tests/test_churn.py
VICTIM, AT, EVT = 0, 5.0, 400.0


def run(name, proto=None, events=(), engine="batched_elastic", trace=False,
        **kw):
    b = workloads.get(name).build("srsp", 4, seed=3, proto=proto, **kw)
    eb = harness.make_elastic(b, events=events)
    state = T.with_trace(eb.state) if trace else eb.state
    fin = harness.runner(engine)(eb.wl, state, *eb.ops)
    res = eb.check(fin)
    rec = float(np.sum(np.asarray(fin.s.store.counters.recoveries)))
    return fin, res, rec


def main(trace=False):
    srsp = P.get_protocol("srsp")
    crash = [(EVT, VICTIM, "crash")]

    print("== worksteal / srsp on the batched elastic engine ==")
    fin, res, rec = run("worksteal", n_chunks_max=12)
    print(f"zero churn:        check={'ok' if res['ok'] else 'FAIL':4s} "
          f"alive={np.asarray(fin.alive).tolist()} recovered={rec:.0f}")

    fin, res, rec = run(
        "worksteal", proto=faults.crash_holding_lock(srsp, VICTIM, AT),
        events=crash, n_chunks_max=12, trace=trace)
    print(f"crash + recovery:  check={'ok' if res['ok'] else 'FAIL':4s} "
          f"alive={np.asarray(fin.alive).tolist()} recovered={rec:.0f} "
          f"(agent {VICTIM} died holding its queue lock at clock {AT:.0f}; "
          f"lease expired at the churn event, drain reclaimed its chunks)")
    if trace:
        doc = export.write_trace("TRACE_churn_demo.json", fin.s.store,
                                 label="worksteal crash+recovery demo")
        print(f"   traced {doc['srsp']['events']} events -> "
              f"TRACE_churn_demo.json (open in https://ui.perfetto.dev)")

    fin, res, rec = run(
        "worksteal",
        proto=faults.lease_never_expires(
            faults.crash_holding_lock(srsp, VICTIM, AT)),
        events=crash, n_chunks_max=12)
    print(f"crash, no lease:   check={'ok' if res['ok'] else 'FAIL':4s} "
          f"alive={np.asarray(fin.alive).tolist()} recovered={rec:.0f} "
          f"lost={res['check_fails']} "
          f"(terminates — loop guard — but the loss is reported)")

    print("\n== kv_directory / srsp: leave then join ==")
    fin, res, rec = run("kv_directory",
                        events=[(50.0, 2, "leave"), (150.0, 2, "join")])
    print(f"leave@50 join@150: check={'ok' if res['ok'] else 'FAIL':4s} "
          f"alive={np.asarray(fin.alive).tolist()} recovered={rec:.0f} "
          f"(agent 2's quota was forgiven at leave, extended at join)")


if __name__ == "__main__":
    main(trace="--trace" in sys.argv[1:])
