"""The paper's technique at the framework layer: asymmetric cross-pod
synchronization of sparsely-updated parameter banks (MoE experts /
embedding rows).

Each simulated pod locally updates the expert blocks its batch routed to
(the pod is the *local sharer* of those blocks).  A periodic global sync is
the *remote acquire*: sRSP-selective sync flushes only the union of dirty
blocks; the RSP-baseline analogue all-reduces the whole bank.

  PYTHONPATH=src python examples/asymmetric_cross_pod.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.hier_sync import bank_init, make_pod_sync


def main():
    n_pods = 4
    mesh = Mesh(np.array(jax.devices()[:n_pods]).reshape(n_pods), ("pod",))
    rng = np.random.default_rng(0)

    # a 32-expert FFN bank: [n_blocks=32 experts, block=4096 words]
    nb, bs = 32, 4096
    base = rng.normal(size=(nb, bs)).astype(np.float32)
    banks = np.broadcast_to(base, (n_pods, nb, bs)).copy()
    print("local steps: each pod trains on its own shard; routing touches")
    for pod in range(n_pods):
        experts = rng.choice(nb, size=3, replace=False)  # top-k routing hits
        banks[pod, experts] += 0.01 * rng.normal(size=(3, bs))
        print(f"  pod{pod}: experts {sorted(experts.tolist())}")

    sh = lambda x: jax.device_put(x, NamedSharding(
        mesh, P(*(("pod",) + (None,) * (x.ndim - 1)))))
    st = jax.tree.map(sh, jax.vmap(bank_init)(
        jnp.asarray(np.broadcast_to(base, (n_pods, nb, bs)).copy())))
    banks_j = sh(jnp.asarray(banks))

    print("\nremote acquire (global sync):")
    for name, selective in (("sRSP selective", True), ("full all-reduce", False)):
        sync = make_pod_sync(mesh, nb, bs, max_dirty=16, selective=selective)
        new_bank, new_st = sync(banks_j, st)
        err = float(jnp.abs(new_bank[0] - jnp.asarray(banks.mean(0))).max())
        moved = float(np.asarray(new_st.bytes_selective)[0])
        print(f"  {name:16s}: bytes_moved={moved/2**20:7.2f} MiB  "
              f"|result - true_mean| = {err:.2e}")
    print("\nsame result, ~{:.0f}x fewer cross-pod bytes for the sparse bank"
          .format(nb / 16))


if __name__ == "__main__":
    main()
