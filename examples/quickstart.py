"""Quickstart: the paper's mechanism in 60 lines.

1. drive the sRSP protocol directly (local release -> remote acquire ->
   selective flush) and watch the cost counters;
2. train a tiny LM for a few steps with the public API.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import protocol as P
from repro.core.costmodel import makespan

# --- 1. the protocol ------------------------------------------------------
cfg = P.ProtoConfig(n_caches=8, n_words=512)
store = P.make_store(cfg)

LOCK, DATA = jnp.int32(64), jnp.int32(5)

# work-group 0 (the LOCAL SHARER) updates shared data and releases locally —
# cheap, L1-only, tracked by sFIFO + LR-TBL
store, _ = P.store_word(cfg, store, 0, DATA, 42)
store = P.local_release(cfg, store, 0, LOCK, 0)
print(f"after local release:  makespan={float(makespan(store.counters)):6.0f} "
      f"l2_accesses={float(store.counters.l2_accesses):4.0f}")

# work-group 5 (a REMOTE SHARER / work-stealer) acquires remotely: sRSP
# probes LR-TBLs, selectively flushes ONLY wg0's dirty blocks, and promotes
store, old = P.srsp_remote_acquire(cfg, store, 5, LOCK, 0, 1)
store, val = P.load(cfg, store, 5, DATA)
print(f"after remote acquire: stolen value={int(val)} (expect 42), "
      f"flushed_blocks={float(store.counters.wb_blocks):3.0f}, "
      f"full_invalidations={float(store.counters.inv_full):3.0f}")

store = P.srsp_remote_release(cfg, store, 5, LOCK, 0)
# wg0's NEXT local acquire is promoted (PA-TBL hit) — and only that one
store, _ = P.local_acquire(cfg, store, 0, LOCK, 0, 1)
print(f"promotions={float(store.counters.promotions):3.0f} (exactly 1: "
      f"selectivity per address)")

# --- 2. train a tiny LM ---------------------------------------------------
from repro.models.registry import get_config
from repro.train.trainer import TrainConfig, Trainer

cfg_lm = get_config("xlstm-125m", smoke=True)
trainer = Trainer(cfg_lm, TrainConfig(steps=10, batch=4, seq=64, lr=3e-3,
                                      log_every=3))
trainer.run()
for m in trainer.metrics_log:
    print(f"step {m['step']:3d}  loss {m['loss']:.4f}  ({m['dt']*1e3:.0f} ms)")
