"""Paper reproduction driver: work-stealing graph workloads under the five
evaluation scenarios (paper §5) — Baseline / Scope-only / Steal-only /
RSP / sRSP — on DIMACS-like synthetic graphs.

  PYTHONPATH=src python examples/worksteal_graphs.py [--wgs 16] [--app pagerank]
"""
import argparse

import numpy as np

from repro.core.worksteal import ENGINES, WSConfig, run_app, reference_solution
from repro.data.graphs import GRAPHS, collab_like, road_like, router_like

SCENARIOS = ["baseline", "scope_only", "steal_only", "rsp", "srsp"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--app", default="pagerank",
                    choices=["pagerank", "sssp", "mis"])
    ap.add_argument("--wgs", type=int, default=16)
    ap.add_argument("--nodes", type=int, default=2048)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--engine", default="batched",
                    choices=sorted(ENGINES),
                    help="vectorized scheduler (default), the serial "
                         "reference engine, or the fused megakernel trip "
                         "(identical counters, see DESIGN.md §4, §12)")
    args = ap.parse_args()

    g = {"pagerank": collab_like, "sssp": road_like,
         "mis": router_like}[args.app](args.nodes)
    print(f"graph={g.name} nnz={g.nnz}  app={args.app}  wgs={args.wgs}\n")
    ws = WSConfig(n_wgs=args.wgs, chunk_cap=32,
                  n_chunks_max=min((g.n + 31) // 32, 256))
    ref = reference_solution(args.app, g, max_iters=args.iters)
    base = None
    print(f"{'scenario':12s} {'makespan':>12s} {'speedup':>8s} {'L2 acc':>9s} "
          f"{'steals':>7s} {'inv':>6s} {'sol ok':>7s}")
    for scen in SCENARIOS:
        r = run_app(args.app, g, scen, ws, max_iters=args.iters,
                    engine=args.engine)
        ok = r.proc_errors == 0
        if args.app == "pagerank":
            ok = ok and np.allclose(r.solution, ref, rtol=1e-4)
        else:
            ok = ok and np.array_equal(r.solution, ref)
        if base is None:
            base = r.makespan
        print(f"{scen:12s} {r.makespan:12.0f} {base/r.makespan:7.2f}x "
              f"{r.counters['l2_accesses']:9.0f} {r.counters['steals']:7.0f} "
              f"{r.counters['inv_full']:6.0f} {str(ok):>7s}")


if __name__ == "__main__":
    main()
