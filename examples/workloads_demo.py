"""Workload subsystem demo: every registered asymmetric-sharing workload
under every protocol scenario, with modeled makespan, L2 traffic, the
consistency self-check verdict, and — per the scope-parametric ISA
(DESIGN.md §9) — whether the workload×protocol pair co-schedules
address-disjoint remote turns (`rbatch`).

  PYTHONPATH=src python examples/workloads_demo.py [--agents 8] [--seed 0]
      [--engine batched] [--scenarios srsp rsp]

Every workload issues its synchronization through `repro.core.ops`
scoped dispatch; scenario and engine names come from the harness
REGISTRIES (`harness.scenarios()` / `harness.engines()`), so protocols
and engines registered by extensions show up here automatically.
Elastic engines (DESIGN.md §10) run each bench wrapped in a zero-churn
alive-set — bitwise identical to the plain engines by contract.
`scope_only` failing its self-check on remote-turn workloads is the
point — local-scope sync is not remote-safe, which is why the paper
needs promotion at all.
"""
import argparse

from repro import workloads
from repro.workloads import harness


def run_bench(b, engine):
    """Run a bench on any registered engine; elastic engines take the
    zero-churn alive-set wrapping (harness.make_elastic)."""
    if engine in ("serial_elastic", "batched_elastic"):
        eb = harness.make_elastic(b)
        fin = harness.runner(engine)(eb.wl, eb.state, *eb.ops)
        return fin.s, eb.check(fin)
    final = harness.runner(engine)(b.wl, b.state, *b.ops)
    return final, b.check(final)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workloads", nargs="+", default=workloads.available())
    ap.add_argument("--engine", choices=harness.engines(), default="batched")
    ap.add_argument("--scenarios", nargs="+", default=None,
                    help=f"subset of {harness.scenarios()}")
    args = ap.parse_args()
    scens = args.scenarios or [s for s in harness.scenarios()
                               if s != "steal_only"]

    for name in args.workloads:
        mod = workloads.get(name)
        print(f"\n== {name} (n_agents={args.agents}, "
              f"engine={args.engine}) ==")
        print(f"{'scenario':12s} {'makespan':>10s} {'L2 acc':>8s} "
              f"{'promos':>7s} {'inv':>5s} {'events':>7s} {'check':>6s} "
              f"{'rbatch':>7s}")
        for scen in scens:
            b = mod.build(scen, args.agents, seed=args.seed)
            final, res = run_bench(b, args.engine)
            c = harness.counters_dict(final.store)
            rbatch = (b.wl.remote_turn_b is not None
                      and b.wl.remote_addr is not None
                      and b.wl.proto.remote_batchable)
            print(f"{scen:12s} {c['makespan']:10.0f} {c['l2_accesses']:8.0f} "
                  f"{c['promotions']:7.0f} {c['inv_full']:5.0f} "
                  f"{res['events']:7d} "
                  f"{'ok' if res['ok'] else 'FAIL':>6s} "
                  f"{'yes' if rbatch else '-':>7s}")


if __name__ == "__main__":
    main()
