"""Workload subsystem demo: every registered asymmetric-sharing workload
under every protocol scenario, with modeled makespan, L2 traffic and the
consistency self-check verdict.

  PYTHONPATH=src python examples/workloads_demo.py [--agents 8] [--seed 0]

`scope_only` failing its self-check on remote-turn workloads is the
point — local-scope sync is not remote-safe, which is why the paper
needs promotion at all.
"""
import argparse

from repro import workloads
from repro.workloads import harness

SCENARIOS = ["baseline", "scope_only", "rsp", "srsp"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workloads", nargs="+", default=workloads.available())
    args = ap.parse_args()

    for name in args.workloads:
        mod = workloads.get(name)
        print(f"\n== {name} (n_agents={args.agents}) ==")
        print(f"{'scenario':12s} {'makespan':>10s} {'L2 acc':>8s} "
              f"{'promos':>7s} {'inv':>5s} {'events':>7s} {'check':>6s}")
        for scen in SCENARIOS:
            b = mod.build(scen, args.agents, seed=args.seed)
            final = harness.run_batched(b.wl, b.state, *b.ops)
            c = harness.counters_dict(final.store)
            res = b.check(final)
            print(f"{scen:12s} {c['makespan']:10.0f} {c['l2_accesses']:8.0f} "
                  f"{c['promotions']:7.0f} {c['inv_full']:5.0f} "
                  f"{res['events']:7d} "
                  f"{'ok' if res['ok'] else 'FAIL':>6s}")


if __name__ == "__main__":
    main()
