PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-slow test-all bench bench-full sweep sweep-smoke

# Tier-1: fast suite (slow-marked full-size sims excluded via pyproject addopts)
test:
	$(PYTHON) -m pytest -x -q

# Only the slow full-size simulator tests
test-slow:
	$(PYTHON) -m pytest -q -m slow

# Everything
test-all:
	$(PYTHON) -m pytest -q -m ""

# Protocol-engine benchmark -> BENCH_protocol_engine.json
# (pagerank, srsp+rsp, n_wgs in {16,64,256}, serial vs batched engine)
bench:
	$(PYTHON) benchmarks/protocol_engine_bench.py --out BENCH_protocol_engine.json

# Full sweep incl. extra apps/scenarios; see --help for knobs
bench-full:
	$(PYTHON) benchmarks/protocol_engine_bench.py --apps pagerank sssp \
	  --scenarios baseline steal_only rsp srsp --out BENCH_protocol_engine.json

# Workload-subsystem sweep: protocol x workload x n_agents grid plus the
# donation and packed-metadata A/Bs -> BENCH_workloads.json
# (schema: benchmarks/SCHEMA.md)
sweep:
	$(PYTHON) -m repro.workloads.sweep --out BENCH_workloads.json

# CI smoke: 1 replica, n_agents=16 grid, no subprocess A/Bs — catches
# sweep-schema regressions in PR instead of at bench time.  The output is
# a scratch file; the committed BENCH_workloads.json comes from `make sweep`.
sweep-smoke:
	$(PYTHON) -m repro.workloads.sweep --sizes 16 --seeds 1 --iters 1 \
	  --no-donation --no-pack-ab --remote-batch-sizes 16 \
	  --out BENCH_workloads.smoke.json
	$(PYTHON) -c "import json; d=json.load(open('BENCH_workloads.smoke.json')); \
	  assert d['schema_version'] == 5 and d['runs'], d.get('schema_version'); \
	  bad=[r for r in d['runs'] if not r['check_ok'] \
	       and r['scenario'] != 'scope_only']; \
	  assert not bad, bad; \
	  assert all(r['api'] == 'scoped' for r in d['runs']); \
	  rb=[r for r in d['runs'] if r['remote_batch']]; \
	  assert rb, 'no remote-batch-capable cell in the grid'; \
	  ab=d['remote_batch_ab']; \
	  assert ab and all(r['check_ok'] for r in ab), ab; \
	  ch=[r for r in d['runs'] if r['churn_events']]; \
	  assert ch, 'no churned crash-recovery cell'; \
	  assert all(r['check_ok'] and r['recovered'] > 0 \
	             and r['lost_updates'] == 0 for r in ch), ch; \
	  print('sweep smoke OK:', len(d['runs']), 'cells,', \
	        len(rb), 'remote-batch cells,', len(ch), 'churned')"
