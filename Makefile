PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-slow test-all bench bench-full sweep

# Tier-1: fast suite (slow-marked full-size sims excluded via pyproject addopts)
test:
	$(PYTHON) -m pytest -x -q

# Only the slow full-size simulator tests
test-slow:
	$(PYTHON) -m pytest -q -m slow

# Everything
test-all:
	$(PYTHON) -m pytest -q -m ""

# Protocol-engine benchmark -> BENCH_protocol_engine.json
# (pagerank, srsp+rsp, n_wgs in {16,64,256}, serial vs batched engine)
bench:
	$(PYTHON) benchmarks/protocol_engine_bench.py --out BENCH_protocol_engine.json

# Full sweep incl. extra apps/scenarios; see --help for knobs
bench-full:
	$(PYTHON) benchmarks/protocol_engine_bench.py --apps pagerank sssp \
	  --scenarios baseline steal_only rsp srsp --out BENCH_protocol_engine.json

# Workload-subsystem sweep: protocol x workload x n_agents grid plus the
# buffer-donation A/B -> BENCH_workloads.json (schema: benchmarks/SCHEMA.md)
sweep:
	$(PYTHON) -m repro.workloads.sweep --out BENCH_workloads.json
