PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-slow test-all bench bench-full bench-kernels sweep \
	sweep-smoke trace bench-compare traffic

# Tier-1: fast suite (slow-marked full-size sims excluded via pyproject addopts)
test:
	$(PYTHON) -m pytest -x -q

# Only the slow full-size simulator tests
test-slow:
	$(PYTHON) -m pytest -q -m slow

# Everything
test-all:
	$(PYTHON) -m pytest -q -m ""

# Protocol-engine benchmark -> BENCH_protocol_engine.json
# (pagerank, srsp+rsp, n_wgs in {16,64,256}, serial vs batched engine)
bench:
	$(PYTHON) benchmarks/protocol_engine_bench.py --out BENCH_protocol_engine.json

# Full sweep incl. extra apps/scenarios; see --help for knobs
bench-full:
	$(PYTHON) benchmarks/protocol_engine_bench.py --apps pagerank sssp \
	  --scenarios baseline steal_only rsp srsp --out BENCH_protocol_engine.json

# Kernel micro-benchmarks (CSV to stdout): per-kernel jnp-reference wall
# times incl. the fused-turn trip-plan and plane-commit surfaces at
# n_wgs in {64,256,1024}, packed and boolean metadata layouts
bench-kernels:
	$(PYTHON) benchmarks/kernel_bench.py

# Workload-subsystem sweep: protocol x workload x n_agents grid plus the
# donation and packed-metadata A/Bs -> BENCH_workloads.json
# (schema: benchmarks/SCHEMA.md)
sweep:
	$(PYTHON) -m repro.workloads.sweep --out BENCH_workloads.json

# CI smoke: 1 replica, n_agents=16 grid, no subprocess A/Bs — catches
# sweep-schema regressions in PR instead of at bench time.  Runs under
# REPRO_TRACE=1 so the schema-v6 latency columns and the Perfetto export
# are exercised too; benchmarks/check_smoke.py carries the structural
# assertions.  The committed BENCH_workloads.json comes from `make sweep`.
sweep-smoke:
	env REPRO_TRACE=1 $(PYTHON) -m repro.workloads.sweep --sizes 16 \
	  --seeds 1 --iters 1 --no-donation --no-pack-ab \
	  --remote-batch-sizes 16 --no-fuse-ab --no-serving \
	  --out BENCH_workloads.smoke.json --trace-out TRACE_sweep.json
	$(PYTHON) benchmarks/check_smoke.py BENCH_workloads.smoke.json \
	  --expect-trace

# Trace-driven serving demo (DESIGN.md §13): generate + replay a
# Zipf-skewed bursty trace through kv_serving and print the request
# latency percentiles per scenario
traffic:
	$(PYTHON) examples/kv_serving_demo.py

# Trace the pinned crash-recovery demo cell and export Perfetto JSON
# (load TRACE_demo.json at https://ui.perfetto.dev); see README
# "Observability".
trace:
	$(PYTHON) -m repro.obs.report --demo --out TRACE_demo.json

# Bench regression gate: fresh smoke sweep vs the committed smoke
# baseline (BENCH_workloads.smoke.json).  Exits nonzero on regressed
# makespan / latency_p99 / srsp-vs-baseline ratios; CI runs the same
# diff with --advisory.
bench-compare:
	env REPRO_TRACE=1 $(PYTHON) -m repro.workloads.sweep --sizes 16 \
	  --seeds 1 --iters 1 --no-donation --no-pack-ab \
	  --remote-batch-sizes 16 --no-fuse-ab --no-serving \
	  --out BENCH_workloads.smoke.new.json --trace-out TRACE_sweep.new.json
	$(PYTHON) benchmarks/compare.py BENCH_workloads.smoke.json \
	  BENCH_workloads.smoke.new.json
