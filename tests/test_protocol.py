"""Protocol semantics tests: data propagation, promotion, staleness, and the
dirty⊆sFIFO flush-completeness invariant (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements.txt); the hypothesis-free "
                    "protocol checks live in test_engine_equivalence.py")
from hypothesis import given, settings, strategies as st

from repro.core import protocol as P
from repro.core import tables

CFG = P.ProtoConfig(n_caches=4, n_words=256)


def fresh():
    return P.make_store(CFG)


LOCK = jnp.int32(64)
DATA = jnp.int32(5)


def test_srsp_propagates_remote_data():
    st_ = fresh()
    st_, _ = P.store_word(CFG, st_, 0, DATA, 42)
    st_ = P.local_release(CFG, st_, 0, LOCK, 0)
    st_, old = P.srsp_remote_acquire(CFG, st_, 1, LOCK, 0, 1)
    assert int(old) == 0
    st_, v = P.load(CFG, st_, 1, DATA)
    assert int(v) == 42


def test_without_promotion_thief_reads_stale():
    """The adversarial schedule the paper's mechanism exists to prevent:
    a thief doing only a LOCAL acquire sees stale data."""
    st_ = fresh()
    # thief caches DATA=0 first
    st_, v0 = P.load(CFG, st_, 1, DATA)
    # owner updates DATA and releases locally
    st_, _ = P.store_word(CFG, st_, 0, DATA, 42)
    st_ = P.local_release(CFG, st_, 0, LOCK, 0)
    # thief local-acquires (wrong scope!) and reads
    st_, _ = P.local_acquire(CFG, st_, 1, LOCK, 0, 1)
    st_, v = P.load(CFG, st_, 1, DATA)
    assert int(v) == 0  # stale — the memory model really models staleness


def test_pa_tbl_promotes_next_local_acquire():
    st_ = fresh()
    st_ = P.local_release(CFG, st_, 0, LOCK, 0)
    st_, _ = P.srsp_remote_acquire(CFG, st_, 1, LOCK, 0, 1)
    st_ = P.srsp_remote_release(CFG, st_, 1, LOCK, 0)
    pre = float(st_.counters.promotions)
    st_, old = P.local_acquire(CFG, st_, 0, LOCK, 0, 1)
    assert float(st_.counters.promotions) == pre + 1
    assert int(old) == 0  # saw the remote release's fresh value


def test_local_acquire_other_addr_stays_cheap():
    st_ = fresh()
    st_ = P.local_release(CFG, st_, 0, LOCK, 0)
    st_, _ = P.srsp_remote_acquire(CFG, st_, 1, LOCK, 0, 1)
    st_ = P.srsp_remote_release(CFG, st_, 1, LOCK, 0)
    other = jnp.int32(128)
    pre = float(st_.counters.promotions)
    st_, _ = P.local_acquire(CFG, st_, 2, other, 0, 1)
    assert float(st_.counters.promotions) == pre  # selectivity per address


def test_rsp_cost_exceeds_srsp():
    def run(acq, rel):
        st_ = fresh()
        st_, _ = P.store_word(CFG, st_, 0, DATA, 7)
        st_ = P.local_release(CFG, st_, 0, LOCK, 0)
        st_, _ = acq(CFG, st_, 1, LOCK, 0, 1)
        st_ = rel(CFG, st_, 1, LOCK, 0)
        return float(jnp.max(st_.counters.cycles)), float(st_.counters.inv_full)

    c_rsp, inv_rsp = run(P.rsp_remote_acquire, P.rsp_remote_release)
    c_srsp, inv_srsp = run(P.srsp_remote_acquire, P.srsp_remote_release)
    assert c_srsp < c_rsp
    assert inv_srsp < inv_rsp


def test_same_cu_optimization():
    """§4.2: if the remote acquirer shares the L1 with the local sharer, no
    probe broadcast / no own invalidate."""
    st_ = fresh()
    st_, _ = P.store_word(CFG, st_, 0, DATA, 9)
    st_ = P.local_release(CFG, st_, 0, LOCK, 0)
    pre_inv = float(st_.counters.inv_full)
    pre_probe = float(st_.counters.probes)
    st_, old = P.srsp_remote_acquire(CFG, st_, 0, LOCK, 0, 1)  # same cache!
    assert int(old) == 0
    assert float(st_.counters.inv_full) == pre_inv
    assert float(st_.counters.probes) == pre_probe


def _dirty_subset_of_fifo(st_) -> bool:
    """Invariant: every dirty word's block is in that cache's sFIFO."""
    wd = np.asarray(P.wdirty_bool(st_))   # block-major [n, n_blocks, W]
    addrs = np.asarray(st_.fifo.addrs)
    for c in range(CFG.n_caches):
        blocks = set(np.nonzero(wd[c].any(axis=-1))[0])
        fifo_blocks = set(a for a in addrs[c] if a >= 0)
        if not blocks.issubset(fifo_blocks):
            return False
    return True


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 4),
                          st.integers(0, 15)), max_size=30))
def test_flush_completeness_invariant(ops):
    """Random op soup; after every op, dirty ⊆ sFIFO (so a drain is a
    complete flush), and a final drain_all leaves no dirty words."""
    st_ = fresh()
    for cid, op, a in ops:
        addr = jnp.int32(a * 16 + 3)
        if op == 0:
            st_, _ = P.store_word(CFG, st_, cid, addr, a)
        elif op == 1:
            st_, _ = P.load(CFG, st_, cid, addr)
        elif op == 2:
            st_ = P.local_release(CFG, st_, cid, addr, 1)
        elif op == 3:
            st_, _ = P.local_acquire(CFG, st_, cid, addr, 0, 1)
        else:
            st_, _ = P.srsp_remote_acquire(CFG, st_, cid, addr, 0, 1)
    assert _dirty_subset_of_fifo(st_)
    for c in range(CFG.n_caches):
        st_, _ = P.drain_fifo_all(CFG, st_, c)
    assert not bool(np.asarray(P.wdirty_bool(st_)).any())


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_well_synchronized_transfer_random(seed):
    """Random disciplined producer/consumer rounds always transfer the
    latest value under sRSP, even when ownership MIGRATES between caches.

    Discipline (the paper's asymmetric-sharing model): a cache becomes the
    local sharer by first acquiring the lock remotely; the probe path then
    consumes the previous sharer's LR entry, so at most one LR entry per
    address exists at any time."""
    rng = np.random.default_rng(seed)
    st_ = fresh()
    val = 0
    for _ in range(6):
        owner, reader = rng.integers(0, 4, 2)
        val += 1
        # ownership handoff: acquire before writing
        st_, _ = P.srsp_remote_acquire(CFG, st_, int(owner), LOCK, 0, 1)
        st_, _ = P.store_word(CFG, st_, int(owner), DATA, int(val))
        st_ = P.local_release(CFG, st_, int(owner), LOCK, 0)
        # reader steals the freshest value
        st_, _ = P.srsp_remote_acquire(CFG, st_, int(reader), LOCK, 0, 1)
        st_, v = P.load(CFG, st_, int(reader), DATA)
        assert int(v) == val, (seed, val, int(v))
        st_ = P.srsp_remote_release(CFG, st_, int(reader), LOCK, 0)
        # single-local-sharer invariant: at most one LR entry for LOCK
        lr_addrs = np.asarray(st_.lr.addrs)
        assert int((lr_addrs == int(LOCK)).sum()) <= 1
