"""Trace-driven traffic subsystem properties (ISSUE 9, DESIGN.md §13).

Property tests over the three layers:

1. **samplers** — Zipf rank frequencies are monotone non-increasing in
   rank; arrival clocks are sorted and non-negative for every
   (gap_mean, burstiness, burst_len) cell; burstiness=1.0 degenerates to
   Poisson (the burst envelope becomes the identity, so `burst_len`
   cannot matter).
2. **trace** — `generate` is bitwise-replayable from (seed, config),
   distinct seeds actually differ, cross-owner requests are forced to
   reads, and `save`/`load` round-trips columns + provenance exactly.
3. **driver** — `from_trace` regroups without losing requests, per-agent
   streams stay arrival-sorted, `lbnr` matches a host-side reference
   loop, and retire/admit move only the quota (never the columns).

The properties run on a seeded parameter grid so they hold without any
external dependency; when Hypothesis is installed the replay property
additionally fuzzes over random seeds.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.traffic import driver as D
from repro.traffic import samplers as S
from repro.traffic import trace as TR

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # container has no hypothesis; the grid versions
    HAVE_HYPOTHESIS = False   # of every property still run

N_AGENTS = 4
N_KEYS = 8


def _cfg(**kw):
    return dataclasses.replace(S.TrafficConfig(), **kw)


# --------------------------------------------------------------- samplers

@pytest.mark.parametrize("s", [0.9, 1.1, 1.5])
def test_zipf_frequency_monotone_in_rank(s):
    """More popular (lower) ranks must be drawn at least as often."""
    ranks = S.zipf_ranks(jax.random.PRNGKey(7), 40_000, N_KEYS, s)
    counts = np.bincount(np.asarray(ranks), minlength=N_KEYS)
    assert counts.sum() == 40_000
    assert np.all(np.diff(counts) <= 0), counts


def test_zipf_ranks_in_range():
    ranks = np.asarray(S.zipf_ranks(jax.random.PRNGKey(3), 4096, N_KEYS, 1.2))
    assert ranks.min() >= 0 and ranks.max() < N_KEYS


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("burstiness,burst_len", [(1.0, 8), (4.0, 8),
                                                  (4.0, 3), (16.0, 1)])
def test_arrivals_sorted_and_nonnegative(seed, burstiness, burst_len):
    cfg = _cfg(burstiness=burstiness, burst_len=burst_len, gap_mean=16.0)
    arr = np.asarray(S.arrival_clocks(jax.random.PRNGKey(seed), 64, cfg))
    assert np.all(arr >= 0.0)
    assert np.all(np.diff(arr) >= 0.0)


def test_burstiness_one_is_poisson():
    """With burstiness=1.0 the on/off envelope is identically 1.0, so the
    phase geometry (burst_len) cannot change a single clock."""
    key = jax.random.PRNGKey(11)
    a = S.arrival_clocks(key, 64, _cfg(burstiness=1.0, burst_len=8))
    b = S.arrival_clocks(key, 64, _cfg(burstiness=1.0, burst_len=3))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bursty_arrivals_cluster():
    """burstiness >> 1 must raise gap variance over Poisson (same draws)."""
    key = jax.random.PRNGKey(5)
    flat = np.diff(np.asarray(S.arrival_clocks(key, 512, _cfg())))
    bursty = np.diff(np.asarray(S.arrival_clocks(
        key, 512, _cfg(burstiness=8.0))))
    assert bursty.var() > 2.0 * flat.var()


def test_request_kinds_and_remote_draws_are_bernoulli_like():
    kinds = np.asarray(S.request_kinds(jax.random.PRNGKey(2), 4096, 0.25))
    assert set(np.unique(kinds)) <= {0, 1}
    assert 0.15 < kinds.mean() < 0.35
    rem = np.asarray(S.remote_draws(jax.random.PRNGKey(2), 4096, 0.125))
    assert rem.dtype == bool
    assert 0.05 < rem.mean() < 0.20


# ------------------------------------------------------------------ trace

@pytest.mark.parametrize("seed", [0, 3, 17])
def test_generate_is_bitwise_replayable(seed):
    cfg = _cfg(requests_per_agent=32, burstiness=4.0)
    a = TR.generate(cfg, N_AGENTS, N_KEYS, seed)
    b = TR.generate(cfg, N_AGENTS, N_KEYS, seed)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_distinct_seeds_differ():
    cfg = _cfg(requests_per_agent=32)
    a = TR.generate(cfg, N_AGENTS, N_KEYS, 0)
    b = TR.generate(cfg, N_AGENTS, N_KEYS, 1)
    assert not np.array_equal(np.asarray(a.key), np.asarray(b.key))


def test_trace_shape_and_canonical_order():
    cfg = _cfg(requests_per_agent=24)
    tr = TR.generate(cfg, N_AGENTS, N_KEYS, 7)
    m = N_AGENTS * cfg.requests_per_agent
    assert all(len(c) == m for c in tr)
    arr = np.asarray(tr.arrival)
    assert np.all(np.diff(arr) >= 0.0)          # globally arrival-sorted
    agent = np.asarray(tr.agent)
    assert np.bincount(agent, minlength=N_AGENTS).tolist() \
        == [cfg.requests_per_agent] * N_AGENTS


def test_cross_owner_requests_are_reads():
    tr = TR.generate(_cfg(requests_per_agent=64, remote_frac=0.5),
                     N_AGENTS, N_KEYS, 9)
    owner = np.asarray(TR.owner(tr.key, N_AGENTS))
    kind = np.asarray(tr.kind)
    agent = np.asarray(tr.agent)
    remote = owner != agent
    assert remote.any()                          # the property is exercised
    assert np.all(kind[remote] == 0)


def test_generate_rejects_ragged_placement():
    with pytest.raises(ValueError):
        TR.generate(_cfg(), n_agents=3, n_keys=8, seed=0)


def test_save_load_roundtrip_bitwise(tmp_path):
    cfg = _cfg(requests_per_agent=16, zipf_s=1.3, burstiness=2.0)
    tr = TR.generate(cfg, N_AGENTS, N_KEYS, 5)
    path = str(tmp_path / "trace.npz")
    TR.save(path, tr, cfg=cfg, n_agents=N_AGENTS, n_keys=N_KEYS, seed=5)
    tr2, meta = TR.load(path)
    for la, lb in zip(tr, tr2):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert meta["config"] == cfg
    assert (meta["n_agents"], meta["n_keys"], meta["seed"]) \
        == (N_AGENTS, N_KEYS, 5)
    # provenance closes the loop: regenerating from the saved meta
    # reproduces the saved columns bitwise
    tr3 = TR.generate(meta["config"], meta["n_agents"], meta["n_keys"],
                      meta["seed"])
    np.testing.assert_array_equal(np.asarray(tr.key), np.asarray(tr3.key))


def test_generate_vmaps_over_seeds():
    cfg = _cfg(requests_per_agent=8)
    stack = jax.vmap(lambda s: TR.generate(cfg, N_AGENTS, N_KEYS, s))(
        jnp.arange(3, dtype=jnp.uint32))
    solo = TR.generate(cfg, N_AGENTS, N_KEYS, 2)
    np.testing.assert_array_equal(np.asarray(stack.key[2]),
                                  np.asarray(solo.key))


# ----------------------------------------------------------------- driver

def _streams(seed=7, m=32, **kw):
    cfg = _cfg(requests_per_agent=m, **kw)
    tr = TR.generate(cfg, N_AGENTS, N_KEYS, seed)
    return TR.generate(cfg, N_AGENTS, N_KEYS, seed), \
        D.from_trace(tr, N_AGENTS, m)


def test_from_trace_preserves_requests_per_agent():
    tr, st = _streams()
    for a in range(N_AGENTS):
        mine = np.asarray(tr.key)[np.asarray(tr.agent) == a]
        np.testing.assert_array_equal(np.sort(np.asarray(st.key[a])),
                                      np.sort(mine))
        arr = np.asarray(st.arrival[a])
        assert np.all(np.diff(arr) >= 0.0)       # per-lane order kept


def test_lbnr_matches_reference_loop():
    _, st = _streams(remote_frac=0.4)
    rem = np.asarray(st.remote)
    n, m = rem.shape
    ref = np.zeros((n, m), np.int32)
    for i in range(n):
        run = 0
        for j in reversed(range(m)):
            run = 0 if rem[i, j] else run + 1
            ref[i, j] = run
    np.testing.assert_array_equal(np.asarray(st.lbnr), ref)


def test_driver_predicates_partition_pending():
    _, st = _streams(remote_frac=0.4)
    cursor = jnp.zeros(N_AGENTS, jnp.int32)
    loc = np.asarray(D.can_local(st, cursor))
    rem = np.asarray(D.can_remote(st, cursor))
    pend = np.asarray(D.pending(st, cursor))
    assert np.all(loc ^ rem == pend) and not np.any(loc & rem)


def test_remote_bound_fence_and_exhaustion():
    _, st = _streams(remote_frac=0.4, m=8)
    cursor = jnp.zeros(N_AGENTS, jnp.int32)
    bound = np.asarray(D.remote_bound(st, cursor, 20.0))
    np.testing.assert_allclose(bound,
                               np.asarray(st.lbnr[:, 0]) * 20.0)
    done = jnp.full(N_AGENTS, 8, jnp.int32)
    assert np.all(np.asarray(D.remote_bound(st, done, 20.0)) >= 1e38)


def test_wait_cycles_clamp():
    _, st = _streams(m=8)
    cursor = jnp.zeros(N_AGENTS, jnp.int32)
    arr = np.asarray(st.arrival[:, 0])
    early = np.asarray(D.wait_cycles(st, cursor,
                                     jnp.zeros(N_AGENTS, jnp.float32)))
    np.testing.assert_allclose(early, arr)
    late = np.asarray(D.wait_cycles(
        st, cursor, jnp.full(N_AGENTS, 1e9, jnp.float32)))
    np.testing.assert_array_equal(late, np.zeros(N_AGENTS))


def test_retire_admit_touch_only_quota():
    _, st = _streams(m=8)
    cursor = jnp.full(N_AGENTS, 3, jnp.int32)
    dead = jnp.asarray([True, False, False, False])
    st2 = D.retire(st, cursor, dead)
    assert np.asarray(st2.quota).tolist() == [3, 8, 8, 8]
    np.testing.assert_array_equal(np.asarray(st2.key), np.asarray(st.key))
    st3 = D.admit(st2, cursor, dead)
    assert np.asarray(st3.quota).tolist() == [4, 8, 8, 8]
    # all-False churn is the identity (the elastic zero-churn contract)
    st4 = D.retire(st, cursor, jnp.zeros(N_AGENTS, bool))
    np.testing.assert_array_equal(np.asarray(st4.quota),
                                  np.asarray(st.quota))


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_hypothesis_replay_any_seed(seed):
        cfg = _cfg(requests_per_agent=8)
        a = TR.generate(cfg, N_AGENTS, N_KEYS, seed)
        b = TR.generate(cfg, N_AGENTS, N_KEYS, seed)
        for la, lb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        arr = np.asarray(a.arrival)
        assert np.all(arr >= 0.0) and np.all(np.diff(arr) >= 0.0)
