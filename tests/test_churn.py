"""Elastic alive-set scheduling + crash-recovery drains (ISSUE 6).

Four contracts:

1. **zero-churn identity** — an elastic engine with an empty churn
   schedule is bitwise identical to the plain engine it wraps (the
   fences reduce to `clock < BIG` and the fire branch never runs).
   Complementary pins live in tests/test_workloads.py and
   tests/test_engine_equivalence.py; here the elastic-vs-elastic and
   churned cases are covered.
2. **churned serial == batched** — churn events serialize against every
   turn at clock >= their fire time in BOTH engines, so the batched
   elastic engine stays bitwise equal to the serial one even mid-churn.
3. **red/green crash recovery** — for every registered workload there is
   a pinned crash injection (faults.crash_holding_lock /
   faults.crash_dirty) where the self-check goes RED when the lease
   never expires (faults.lease_never_expires: no recovery drain) and
   GREEN when the recovery drain runs, with recoveries counted.
4. **termination** — the wedged RED runs still terminate (the elastic
   loop guard exits when no live agent can act or the round budget is
   spent); a crash must never hang the suite.

The pinned (at, evt) clocks below are tuned to the default CostParams:
the crash must land while the victim is inside/holding work and the
CRASH churn event must fire late enough that the victim provably takes
the lock first, but early enough that the run is still in flight.  If
cost parameters change, re-tune by sweeping `at` over the victim's
active window and keeping `evt - at` of a few turn lengths (see the
per-workload notes).
"""
import jax
import numpy as np
import pytest

from repro import workloads
from repro.core import protocol as P
from repro.obs import trace as T
from repro.workloads import faults, harness

N_AGENTS = 4
SEED = 3


def _bench(name, proto=None, **kw):
    return workloads.get(name).build("srsp", N_AGENTS, seed=SEED,
                                     proto=proto, **kw)


def _run_elastic(bench, engine, events=(), lease=0.0):
    eb = harness.make_elastic(bench, events=events, lease=lease)
    final = harness.runner(engine)(eb.wl, eb.state, *eb.ops)
    return final, eb.check(final)


def _assert_bitwise_equal(a, b, ctx):
    # trace stripped: event order differs across engines by design
    # (tests/test_engine_equivalence.py pins the trace-on contract)
    a, b = T.strip(a), T.strip(b)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=str(ctx))


def _recoveries(final):
    return float(np.sum(np.asarray(final.s.store.counters.recoveries)))


# --------------------------------------------------------------------------
# 1. zero-churn identity (elastic wrapper around every registered workload)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["producer_consumer", "kv_directory"])
def test_zero_churn_bitwise_identical_both_engines(name):
    for plain, elastic in (("serial", "serial_elastic"),
                           ("batched", "batched_elastic")):
        b = _bench(name)
        ref = harness.runner(plain)(b.wl, b.state, *b.ops)
        b2 = _bench(name)
        fin, res = _run_elastic(b2, elastic)
        _assert_bitwise_equal(ref, fin.s, (name, plain))
        assert bool(np.all(np.asarray(fin.alive))), name
        assert res["ok"], (name, res)
    jax.clear_caches()


@pytest.mark.slow
@pytest.mark.parametrize("name", ["reader_lock", "producer_consumer_mc"])
def test_zero_churn_bitwise_identical_more_workloads(name):
    b = _bench(name)
    ref = harness.run_batched(b.wl, b.state, *b.ops)
    b2 = _bench(name)
    fin, res = _run_elastic(b2, "batched_elastic")
    _assert_bitwise_equal(ref, fin.s, name)
    assert res["ok"], (name, res)
    jax.clear_caches()


# --------------------------------------------------------------------------
# 2. churned serial == batched
# --------------------------------------------------------------------------

def test_churned_serial_batched_bitwise_equivalent():
    """Leave+join churn on kv_directory: both elastic engines must agree
    bitwise on every leaf (store, alive mask, recovery clocks)."""
    events = [(50.0, 2, "leave"), (150.0, 2, "join")]
    ser, rs = _run_elastic(_bench("kv_directory"), "serial_elastic", events)
    bat, rb = _run_elastic(_bench("kv_directory"), "batched_elastic", events)
    _assert_bitwise_equal(ser, bat, "kv_directory leave+join")
    assert rs["ok"] and rb["ok"], (rs, rb)
    jax.clear_caches()


def test_leave_then_join_recovers_and_readmits():
    """A LEAVE reclaims immediately (lease 0) and the later JOIN
    re-admits the agent with fresh work; survivors plus the returnee
    all meet their (forgiven/extended) obligations."""
    events = [(50.0, 2, "leave"), (150.0, 2, "join")]
    fin, res = _run_elastic(_bench("kv_directory"), "batched_elastic", events)
    assert res["ok"], res
    assert bool(np.asarray(fin.alive)[2])        # back in the alive set
    assert _recoveries(fin) >= 1.0               # the leave was drained
    jax.clear_caches()


# --------------------------------------------------------------------------
# 3./4. red/green crash recovery per registered workload (+ termination)
# --------------------------------------------------------------------------

# Pinned crash scenarios (tuned to default CostParams — header note):
#   worksteal: agent 0 owns 4 of 6 chunks (n_chunks_max=12); it crashes
#     at clock 5 so its first pop's release never runs.  Once a thief's
#     probe has PA-promoted the queue-0 lock, the stranded lock reaches
#     L2 and every steal CAS fails — two chunks are unreachable until
#     the recovery drain force-releases the victim's leased lock.
#   reader_lock: the writer (agent 0) dies inside a publish at clock
#     100; readers' remote acquires spin on the held lock.
#   kv_directory: agent 2's releases after clock 60 publish the value
#     without the LR insert (crash_dirty) — lookups read stale versions
#     until the recovery drain writes its dirty words back.
#   producer_consumer: producer 3 goes dirty at clock 12, early enough
#     that no healthy release has LR-covered its block yet (a consumer
#     drain inside the zombie window sees the stale count).
PINS = [
    ("worksteal", faults.crash_holding_lock, 0, 5.0, 400.0,
     {"n_chunks_max": 12}),
    ("reader_lock", faults.crash_holding_lock, 0, 100.0, 160.0, {}),
    ("kv_directory", faults.crash_dirty, 2, 60.0, 120.0, {}),
    ("producer_consumer", faults.crash_dirty, 3, 12.0, 30.0, {}),
]


@pytest.mark.parametrize("name,fault,victim,at,evt",
                         [(n, f, v, a, e) for n, f, v, a, e, _ in PINS])
def test_crash_without_recovery_is_red(name, fault, victim, at, evt):
    """Crash + lease_never_expires: the run must TERMINATE (loop guard)
    and the self-check must report the loss among survivors."""
    kw = dict(PINS[[p[0] for p in PINS].index(name)][5])
    proto = faults.lease_never_expires(
        fault(P.get_protocol("srsp"), victim, at))
    fin, res = _run_elastic(_bench(name, proto=proto, **kw),
                            "batched_elastic",
                            events=[(evt, victim, "crash")])
    assert not res["ok"], (name, res)
    assert res["check_fails"] > 0, (name, res)
    assert not bool(np.asarray(fin.alive)[victim])   # victim retired
    assert _recoveries(fin) == 0.0, name             # nothing was drained
    jax.clear_caches()


@pytest.mark.parametrize("name,fault,victim,at,evt",
                         [(n, f, v, a, e) for n, f, v, a, e, _ in PINS])
def test_crash_with_recovery_drain_is_green(name, fault, victim, at, evt):
    """Same crash, lease expires at the churn event: the recovery drain
    reclaims the dead agent's words and survivors finish clean."""
    kw = dict(PINS[[p[0] for p in PINS].index(name)][5])
    proto = fault(P.get_protocol("srsp"), victim, at)
    fin, res = _run_elastic(_bench(name, proto=proto, **kw),
                            "batched_elastic",
                            events=[(evt, victim, "crash")])
    assert res["ok"], (name, res)
    assert _recoveries(fin) >= 1.0, name
    assert not bool(np.asarray(fin.alive)[victim])
    jax.clear_caches()


@pytest.mark.slow
def test_crash_recovery_serial_matches_batched():
    """The worksteal crash pin, green variant, on both elastic engines —
    crash recovery itself is engine-equivalent."""
    name, fault, victim, at, evt, kw = PINS[0]
    events = [(evt, victim, "crash")]
    proto = fault(P.get_protocol("srsp"), victim, at)
    ser, rs = _run_elastic(_bench(name, proto=proto, **kw),
                           "serial_elastic", events)
    bat, rb = _run_elastic(_bench(name, proto=proto, **kw),
                           "batched_elastic", events)
    _assert_bitwise_equal(ser, bat, "worksteal crash green")
    assert rs["ok"] and rb["ok"], (rs, rb)
    jax.clear_caches()
