"""Runtime tests: checkpoint roundtrip, fault-tolerant restart (injected
failure), straggler detection, trainer loss decrease, elastic reshard
(subprocess with 8 forced host devices)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config
from repro.runtime import checkpoint as CK
from repro.runtime.fault import StepTimer
from repro.train.trainer import TrainConfig, Trainer


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                        "nested": {"b": jnp.ones((5,))}},
             "opt": {"step": jnp.int32(7)}}
    CK.save_checkpoint(str(tmp_path), 7, state)
    path = CK.latest_checkpoint(str(tmp_path))
    assert path and path.endswith("step_00000007")
    step, restored = CK.restore_checkpoint(path, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    state = {"x": jnp.zeros(())}
    for s in [1, 2, 3, 4, 5]:
        CK.save_checkpoint(str(tmp_path), s, state, keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_async_checkpoint(tmp_path):
    state = {"x": jnp.arange(10.0)}
    t = CK.save_checkpoint(str(tmp_path), 3, state, async_save=True)
    t.join()
    assert CK.latest_checkpoint(str(tmp_path))


def test_straggler_detection():
    """Deterministic: drive the rolling window directly (wall-clock sleeps
    are unreliable on a loaded host)."""
    t = StepTimer(window=50, z_thresh=3.0)
    t.window.extend([0.010 + 0.0001 * (i % 3) for i in range(20)])

    class _Clock:
        now = 100.0
    t.start = lambda: setattr(_Clock, "now", 100.0)  # type: ignore
    import time as _time
    orig = _time.perf_counter
    t._t0 = 100.0
    _time.perf_counter = lambda: 100.5  # 0.5 s step vs ~10 ms window
    try:
        dt, straggler = t.stop()
    finally:
        _time.perf_counter = orig
    assert straggler and t.stragglers == 1 and dt > 0.4


@pytest.mark.slow
def test_trainer_loss_decreases(tmp_path):
    cfg = get_config("xlstm-125m", smoke=True)
    tcfg = TrainConfig(steps=25, batch=4, seq=64, lr=3e-3, log_every=1)
    tr = Trainer(cfg, tcfg)
    tr.run()
    losses = [m["loss"] for m in tr.metrics_log]
    assert losses[-1] < losses[0] * 0.95, losses[:3] + losses[-3:]


@pytest.mark.slow
def test_fault_tolerant_restart(tmp_path):
    """Inject a failure mid-run; the runner must restore from the last
    checkpoint and finish all steps."""
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    tcfg = TrainConfig(steps=12, batch=2, seq=32, ckpt_dir=str(tmp_path),
                       ckpt_every=5, log_every=1)
    tr = Trainer(cfg, tcfg)
    tr.run(fail_at=8)  # dies after the step-5 checkpoint
    assert tr.restarts == 1
    steps_logged = [m["step"] for m in tr.metrics_log]
    assert max(steps_logged) == tcfg.steps - 1


_ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.runtime import checkpoint as CK
from repro.runtime.elastic import choose_mesh, reshard_restore

tmp = sys.argv[1]
state = {"params": {"w": jnp.arange(64.0).reshape(8, 8),
                    "emb": jnp.arange(32.0).reshape(16, 2)},
         "opt": {"m": {"w": jnp.zeros((8, 8)), "emb": jnp.zeros((16, 2))}}}
# save from an 8-device (4,2) mesh
mesh_a = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
with mesh_a:
    sharded = jax.device_put(state, NamedSharding(mesh_a, P()))
CK.save_checkpoint(tmp, 1, sharded)
# restore onto a (2,2) 4-device mesh
mesh_b = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
step, restored = reshard_restore(CK.latest_checkpoint(tmp), state, mesh_b)
ok = bool(jnp.all(restored["params"]["w"] == state["params"]["w"]))
n_shards = len(restored["params"]["w"].sharding.device_set)
print(json.dumps({"ok": ok, "step": step, "n_shards": n_shards}))
"""


def test_elastic_reshard_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT,
                          str(tmp_path)], capture_output=True, text=True,
                         env=env, cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"] and rec["step"] == 1
