"""Distributed-layer tests (subprocess with 8 forced host devices):
* SPMD sharded train step == unsharded train step (bitwise-ish)
* sRSP selective cross-pod delta sync == full sync when under capacity,
  moves far fewer bytes for sparse updates, falls back safely on overflow
* int8 compression with error feedback converges to the mean."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, *args):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script, *args],
                         capture_output=True, text=True, env=env, cwd=ROOT)
    assert out.returncode == 0, (out.stdout[-1000:], out.stderr[-3000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


_SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.models.registry import build, get_config
from repro.optim import make_optimizer
from repro.sharding import param_shardings, use_mesh
from repro.train.train_step import make_train_step

cfg = get_config("qwen2.5-32b", smoke=True)
model = build(cfg)
opt_init, opt_update = make_optimizer("adamw", lr=1e-3)
step = make_train_step(model, opt_init, opt_update, n_micro=2)
key = jax.random.PRNGKey(0)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)}

params = model.init(key); opt = opt_init(params)
p1, o1, m1 = jax.jit(step)(params, opt, batch)            # single device

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
with use_mesh(mesh):
    p_sh = param_shardings(params, mesh)
    o_sh = param_shardings(opt, mesh)
    f = jax.jit(step, in_shardings=(p_sh, o_sh, None),
                out_shardings=(p_sh, o_sh, None))
    p2, o2, m2 = f(params, opt, batch)
    txt = f.lower(params, opt, batch).compile().as_text()

dmax = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
           for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
has_coll = ("all-reduce" in txt) or ("all-gather" in txt) or \
           ("reduce-scatter" in txt)
print(json.dumps({"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
                  "dmax": dmax, "has_collectives": has_coll}))
"""


@pytest.mark.slow
def test_spmd_matches_single_device():
    rec = _run(_SPMD_SCRIPT)
    assert rec["has_collectives"], "sharded step lowered without collectives?"
    assert abs(rec["loss1"] - rec["loss2"]) < 1e-3
    assert rec["dmax"] < 5e-2  # bf16 params, reduction-order differences


_DELTA_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.distributed.hier_sync import bank_init, make_pod_sync, BankSyncState

N_PODS, NB, BS, MAXD = 4, 64, 32, 16
mesh = Mesh(np.array(jax.devices()[:N_PODS]).reshape(N_PODS), ("pod",))
rng = np.random.default_rng(0)
base = rng.normal(size=(NB, BS)).astype(np.float32)
banks = np.broadcast_to(base, (N_PODS, NB, BS)).copy()
# each pod updates a DISJOINT sparse set of blocks (asymmetric sharing)
touched = {}
for pod in range(N_PODS):
    blocks = rng.choice(NB, size=3, replace=False)
    for b in blocks:
        banks[pod, b] += rng.normal(size=BS).astype(np.float32)
    touched[pod] = blocks.tolist()

st0 = jax.vmap(bank_init)(jnp.asarray(np.broadcast_to(base, (N_PODS, NB, BS)).copy()))
banks_j = jax.device_put(jnp.asarray(banks), NamedSharding(mesh, P("pod", None, None)))
st0 = jax.tree.map(lambda x: jax.device_put(x, NamedSharding(
    mesh, P(*(("pod",) + (None,) * (x.ndim - 1))))), st0)

sel = make_pod_sync(mesh, NB, BS, max_dirty=MAXD, selective=True)
new_bank, new_st = sel(banks_j, st0)
# oracle: plain mean across pods
mean = banks.mean(0)
err = float(np.abs(np.asarray(new_bank) - mean[None]).max())
bytes_sel = float(np.asarray(new_st.bytes_selective)[0])
bytes_full = float(np.asarray(new_st.bytes_full)[0])

# full-sync reference path
full = make_pod_sync(mesh, NB, BS, max_dirty=MAXD, selective=False)
fb, fst = full(banks_j, st0)
err_full = float(np.abs(np.asarray(fb) - mean[None]).max())

# overflow: dirty everything -> selective must fall back to full mean
banks2 = banks + rng.normal(size=banks.shape).astype(np.float32)
banks2_j = jax.device_put(jnp.asarray(banks2), NamedSharding(mesh, P("pod", None, None)))
ob, ost = sel(banks2_j, st0)
err_of = float(np.abs(np.asarray(ob) - banks2.mean(0)[None]).max())
print(json.dumps({"err": err, "err_full": err_full, "err_overflow": err_of,
                  "bytes_sel": bytes_sel, "bytes_full": bytes_full}))
"""


def test_selective_delta_sync_correct_and_cheaper():
    rec = _run(_DELTA_SCRIPT)
    assert rec["err"] < 1e-5, "selective sync != mean of pod deltas"
    assert rec["err_full"] < 1e-5
    assert rec["err_overflow"] < 1e-5, "overflow fallback broken"
    # 12 of 64 blocks dirty -> selective moves ~max_dirty/64 of the bytes
    assert rec["bytes_sel"] < 0.35 * rec["bytes_full"], rec


def test_int8_error_feedback_unbiased():
    import jax.numpy as jnp
    from repro.distributed.compress import (EFState, compress_blocks,
                                            dequantize_int8)
    rng = np.random.default_rng(0)
    delta = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    idx = jnp.arange(8, dtype=jnp.int32)
    ef = EFState(err=jnp.zeros((8, 64), jnp.float32))
    acc = jnp.zeros((8, 64))
    for _ in range(30):
        q, s, ef = compress_blocks(delta, ef, idx)
        acc = acc + dequantize_int8(q, s)
    mean_recon = acc / 30
    np.testing.assert_allclose(np.asarray(mean_recon), np.asarray(delta),
                               rtol=0.05, atol=0.02)
