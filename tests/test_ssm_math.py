"""Chunked-parallel forms vs recurrent oracles: Mamba2 SSD, mLSTM, sLSTM —
including hypothesis sweeps over shapes/chunk sizes and continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.models import ssm

RNG = np.random.default_rng(0)


def _ssd_ref(x, dt, A, B, C):
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    Bh = jnp.repeat(B, h // g, axis=2)
    Ch = jnp.repeat(C, h // g, axis=2)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        dec = jnp.exp(dt[:, t] * A)
        state = state * dec[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", (x * dt[..., None])[:, t], Bh[:, t])
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, Ch[:, t]))
    return jnp.stack(ys, 1), state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_recurrence(chunk):
    b, l, h, p, g, n = 2, 16, 4, 8, 2, 8
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, (b, l, h)).astype(np.float32))
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)).astype(np.float32))
    B = jnp.asarray(RNG.normal(size=(b, l, g, n)).astype(np.float32))
    C = jnp.asarray(RNG.normal(size=(b, l, g, n)).astype(np.float32))
    y, final = ssm.ssd_chunked(x * dt[..., None], dt * A, B, C, chunk)
    y_ref, st_ref = _ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(st_ref),
                               rtol=3e-4, atol=3e-4)


def test_ssd_continuation_equals_single_pass():
    b, l, h, p, g, n = 1, 24, 2, 4, 1, 8
    x = jnp.asarray(RNG.normal(size=(b, l, h, p)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, (b, l, h)).astype(np.float32))
    A = -jnp.ones((h,))
    B = jnp.asarray(RNG.normal(size=(b, l, g, n)).astype(np.float32))
    C = jnp.asarray(RNG.normal(size=(b, l, g, n)).astype(np.float32))
    xd, dA = x * dt[..., None], dt * A
    y_full, _ = ssm.ssd_chunked(xd, dA, B, C, 4)
    y1, s1 = ssm.ssd_chunked(xd[:, :12], dA[:, :12], B[:, :12], C[:, :12], 4)
    y2, _ = ssm.ssd_chunked(xd[:, 12:], dA[:, 12:], B[:, 12:], C[:, 12:], 4,
                            init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=3e-4, atol=3e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([8, 12, 20, 40]),
       st.sampled_from([4, 8, 16]))
def test_mlstm_chunkwise_property(b, l, chunk):
    h, dk = 2, 8
    rng = np.random.default_rng(b * 1000 + l * 10 + chunk)
    q, k, v = (jnp.asarray(rng.normal(size=(b, l, h, dk)).astype(np.float32))
               for _ in range(3))
    ir = jnp.asarray(rng.normal(size=(b, l, h)).astype(np.float32))
    fr = jnp.asarray(rng.normal(size=(b, l, h)).astype(np.float32)) + 2
    hc, _ = ssm.mlstm_chunkwise(q, k, v, ir, fr, chunk=chunk)
    st_ = ssm.mlstm_zero_state(b, h, dk, dk)
    outs = []
    for t in range(l):
        o, st_ = ssm.mlstm_step(q[:, t], k[:, t], v[:, t], ir[:, t],
                                fr[:, t], st_)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(hc),
                               np.asarray(jnp.stack(outs, 1)),
                               rtol=5e-4, atol=5e-4)


def test_slstm_segmented_matches_stepwise():
    b, l, h, dh = 2, 40, 2, 8
    gates = jnp.asarray(RNG.normal(size=(b, l, 4, h, dh)).astype(np.float32))
    rw = jnp.asarray(RNG.normal(size=(4, h, dh, dh)).astype(np.float32)) * 0.3
    st0 = ssm.slstm_zero_state(b, h, dh)
    hseg, _ = ssm.slstm_apply(gates, rw, st0, segment=16)
    st_ = st0
    outs = []
    for t in range(l):
        st_, hh = ssm.slstm_cell(gates[:, t], rw, st_)
        outs.append(hh)
    np.testing.assert_allclose(np.asarray(hseg),
                               np.asarray(jnp.stack(outs, 1)),
                               rtol=3e-4, atol=3e-4)


def test_mamba2_block_decode_matches_full():
    from repro.configs.base import SSMCfg
    scfg = SSMCfg(d_state=8, d_conv=4, expand=2, head_dim=8, n_groups=1,
                  chunk=8)
    d = 32
    p = ssm.mamba2_init(jax.random.PRNGKey(1), d, scfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 16, d)).astype(np.float32))
    y_full, st_full = ssm.mamba2_apply(p, scfg, d, x)
    # step-by-step decode
    di = scfg.expand * d
    st_ = {"conv": jnp.zeros((2, scfg.d_conv - 1,
                              di + 2 * scfg.n_groups * scfg.d_state),
                             jnp.float32),
           "ssm": jnp.zeros((2, di // scfg.head_dim, scfg.head_dim,
                             scfg.d_state), jnp.float32)}
    outs = []
    for t in range(16):
        y, st_ = ssm.mamba2_decode(p, scfg, d, x[:, t:t + 1], st_)
        outs.append(y)
    y_step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st_["ssm"]),
                               np.asarray(st_full["ssm"]),
                               rtol=2e-3, atol=2e-3)
