"""kv_serving workload contracts (ISSUE 9, DESIGN.md §13).

The serving workload replays a trace-driven request stream (Zipf-skewed
keys, bursty arrivals, read/write mix) against hot KV-page ownership.
Contracts, mirroring tests/test_workloads.py + tests/test_churn.py:

1. **engine equivalence** — serial, batched and fused runs of the SAME
   (seed, config) trace agree bitwise on every state leaf (T.strip).
2. **self-check soundness** — srsp/rsp/baseline finish every offered
   request with no lost pages and no stale reads, and the per-request
   latency histogram accounts for exactly the completed requests.
3. **self-check power** — faults.no_promotion and scope_only staleness
   are both caught (red), so the green runs mean something.
4. **vmapped replicas** — every lane of `run_batched_many` equals its
   solo run (the sweep's ≥1e6-request scale cell rides this path).
5. **elastic/churn** — zero churn is bitwise invisible; the pinned
   die-holding-lock crash (victim 0 at clock 30, CRASH event at 180,
   one page per agent so exactly one lock strands) is GREEN with the
   lease recovery drain and RED without it (survivors wedge on the
   stranded hot page and the run cannot complete).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import workloads
from repro.core import protocol as P
from repro.obs import trace as T
from repro.traffic.samplers import TrafficConfig
from repro.workloads import faults, harness

N_AGENTS = 4
SEED = 3
VICTIM, CRASH_AT, CRASH_EVT = 0, 30.0, 180.0   # sweep pins the same cell


def _build(scenario, proto=None, seed=SEED, **kw):
    return workloads.get("kv_serving").build(scenario, N_AGENTS, seed=seed,
                                             proto=proto, **kw)


def _run(scenario, engine, proto=None, seed=SEED, **kw):
    b = _build(scenario, proto=proto, seed=seed, **kw)
    final = harness.runner(engine)(b.wl, b.state, *b.ops)
    return final, b.check


def _assert_bitwise_equal(a, b, ctx):
    a, b = T.strip(a), T.strip(b)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=str(ctx))


def test_serial_batched_fused_bitwise_equivalent():
    ser, check = _run("srsp", "serial")
    bat, _ = _run("srsp", "batched")
    fus, _ = _run("srsp", "fused")
    _assert_bitwise_equal(ser, bat, ("kv_serving", "srsp", "batched"))
    _assert_bitwise_equal(ser, fus, ("kv_serving", "srsp", "fused"))
    res = check(ser)
    assert res["ok"] and res["done"], res
    jax.clear_caches()


@pytest.mark.slow
@pytest.mark.parametrize("scenario", ["rsp", "baseline"])
def test_engines_equivalent_other_scenarios(scenario):
    ser, check = _run(scenario, "serial")
    bat, _ = _run(scenario, "batched")
    fus, _ = _run(scenario, "fused")
    _assert_bitwise_equal(ser, bat, ("kv_serving", scenario, "batched"))
    _assert_bitwise_equal(ser, fus, ("kv_serving", scenario, "fused"))
    assert check(ser)["ok"], scenario
    jax.clear_caches()


def test_every_offered_request_completes_with_latency_accounted():
    fin, check = _run("srsp", "batched")
    res = check(fin)
    assert res["ok"], res
    assert res["completed"] == res["offered"] > 0
    lat = res["latency"]
    assert lat["count"] == res["completed"]
    assert 0 < lat["p50"] <= lat["p95"] <= lat["p99"]


def test_traffic_config_rides_build_kw():
    tc = TrafficConfig(requests_per_agent=8, zipf_s=1.3, burstiness=4.0)
    fin, check = _run("srsp", "batched", traffic=tc)
    res = check(fin)
    assert res["ok"], res
    assert res["offered"] == N_AGENTS * tc.requests_per_agent


def test_no_promotion_is_caught():
    broken = faults.no_promotion(P.get_protocol("srsp"))
    fin, check = _run("srsp", "batched", proto=broken)
    res = check(fin)
    assert not res["ok"], res
    jax.clear_caches()


def test_scope_only_staleness_is_caught():
    fin, check = _run("scope_only", "batched")
    res = check(fin)
    assert not res["ok"], res
    assert res["check_fails"] > 0, res
    jax.clear_caches()


def test_vmapped_replicas_match_solo_runs():
    m = workloads.get("kv_serving")
    b = m.build("srsp", N_AGENTS, seed=0)
    states = jax.vmap(lambda s: m.init_state(b.wl, s))(jnp.arange(2))
    outs = harness.run_batched_many(b.wl, states)
    for k in range(2):
        solo = m.build("srsp", N_AGENTS, seed=k)
        ref = harness.run_batched(solo.wl, solo.state)
        lane = jax.tree.map(lambda x: x[k], outs)
        # rounds may drift (finished replicas idle while stragglers run)
        _assert_bitwise_equal(ref._replace(rounds=jnp.int32(0)),
                              lane._replace(rounds=jnp.int32(0)), k)
        assert m.self_check(solo.wl, lane)["ok"]
    jax.clear_caches()


def test_zero_churn_elastic_pin():
    b = _build("srsp")
    ref = harness.run_batched(b.wl, b.state, *b.ops)
    b2 = _build("srsp")
    eb = harness.make_elastic(b2)
    fin = harness.run_batched_elastic(eb.wl, eb.state, *eb.ops)
    _assert_bitwise_equal(ref, fin.s, "kv_serving zero-churn")
    assert bool(np.all(np.asarray(fin.alive)))
    jax.clear_caches()


def _run_crash(proto):
    b = _build("srsp", proto=proto, pages_per_agent=1)
    eb = harness.make_elastic(b, events=[(CRASH_EVT, VICTIM, "crash")])
    fin = harness.run_batched_elastic(eb.wl, eb.state, *eb.ops)
    return fin, eb.check(fin)


@pytest.mark.parametrize("seed", [0, SEED])
def test_crash_with_recovery_drain_is_green(seed):
    """The owner of the hottest shard dies holding its page lock; the
    recovery drain writes its committed pages back and force-releases the
    lock, so survivors' skewed lookups of that page all complete."""
    proto = faults.crash_holding_lock(P.get_protocol("srsp"), VICTIM,
                                      CRASH_AT)
    b = workloads.get("kv_serving").build("srsp", N_AGENTS, seed=seed,
                                          proto=proto, pages_per_agent=1)
    eb = harness.make_elastic(b, events=[(CRASH_EVT, VICTIM, "crash")])
    fin = harness.run_batched_elastic(eb.wl, eb.state, *eb.ops)
    res = eb.check(fin)
    assert res["ok"] and res["done"], res
    assert float(np.sum(np.asarray(
        fin.s.store.counters.recoveries))) >= 1.0
    assert not bool(np.asarray(fin.alive)[VICTIM])
    # the victim's unserved tail was forgiven, not silently completed
    assert res["completed"] < res["offered"], res
    jax.clear_caches()


def test_crash_without_recovery_is_red():
    """Same crash, lease never expires: the stranded hot-page lock wedges
    every survivor that needs it — the run must terminate (loop guard)
    and report incompletion, never silent corruption."""
    proto = faults.lease_never_expires(faults.crash_holding_lock(
        P.get_protocol("srsp"), VICTIM, CRASH_AT))
    fin, res = _run_crash(proto)
    assert not res["ok"], res
    assert not res["done"], res
    assert float(np.sum(np.asarray(
        fin.s.store.counters.recoveries))) == 0.0
    jax.clear_caches()
