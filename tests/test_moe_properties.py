"""Hypothesis property tests for the MoE dispatch invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.models import moe as M
from repro.models.registry import get_config

BASE = get_config("granite-moe-1b-a400m", smoke=True)


def _cfg(cf: float, groups: int = 4):
    return dataclasses.replace(
        BASE, moe=dataclasses.replace(BASE.moe, capacity_factor=cf,
                                      dispatch_groups=groups))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([8, 16, 32]),
       st.sampled_from([1, 2, 4]))
def test_no_drop_dispatch_is_grouping_invariant(seed, t, groups):
    """With capacity >= tokens, output must not depend on group blocking."""
    cfg1 = _cfg(float(BASE.moe.n_experts), groups=1)
    cfgg = _cfg(float(BASE.moe.n_experts), groups=groups)
    p = M.moe_init(jax.random.PRNGKey(7), cfg1, jnp.float32)
    x = jnp.asarray(np.random.default_rng(seed).normal(
        size=(t, cfg1.d_model)).astype(np.float32))
    y1, _, c1 = M.moe_apply(p, cfg1, x)
    yg, _, cg = M.moe_apply(p, cfgg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(yg),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(cg))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_expert_counts_conserve_assignments(seed):
    cfg = _cfg(1.25)
    p = M.moe_init(jax.random.PRNGKey(7), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(seed).normal(
        size=(32, cfg.d_model)).astype(np.float32))
    _, _, counts = M.moe_apply(p, cfg, x)
    assert float(counts.sum()) == 32 * cfg.moe.top_k


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_dropped_capacity_only_shrinks_output(seed):
    """Capacity drops zero some contributions; they never invent energy:
    ||y_dropped|| <= ||y_full|| + combine-weight slack."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32, BASE.d_model)).astype(np.float32))
    p = M.moe_init(jax.random.PRNGKey(7), _cfg(1.0), jnp.float32)
    y_drop, _, _ = M.moe_apply(p, _cfg(0.5), x)
    y_full, _, _ = M.moe_apply(p, _cfg(float(BASE.moe.n_experts)), x)
    assert float(jnp.linalg.norm(y_drop)) <= \
        float(jnp.linalg.norm(y_full)) * 1.5 + 1e-3


def test_grads_flow_through_dispatch():
    cfg = _cfg(2.0)
    p = M.moe_init(jax.random.PRNGKey(7), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(16, cfg.d_model)).astype(np.float32))

    def loss(pp):
        y, aux, _ = M.moe_apply(pp, cfg, x)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    # router must receive gradient (through combine weights + aux loss)
    assert float(jnp.abs(g["router"]).sum()) > 0
