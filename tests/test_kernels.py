"""Per-kernel validation: shape/dtype sweeps, interpret=True vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.selective_flush import selective_flush, selective_apply
from repro.kernels.selective_flush.ref import (selective_flush_ref,
                                               selective_apply_ref)
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode import flash_decode
from repro.kernels.flash_decode.ref import decode_attention_ref
from repro.kernels.topk_router import topk_router
from repro.kernels.topk_router.ref import topk_router_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("nb,bs,nd", [(16, 128, 4), (64, 256, 16),
                                      (128, 512, 32), (8, 128, 8)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_selective_flush_sweep(nb, bs, nd, dtype):
    bank = jnp.asarray(RNG.normal(size=(nb, bs)).astype(np.float32)).astype(dtype)
    idx = jnp.asarray(RNG.integers(-1, nb, size=nd).astype(np.int32))
    out = selective_flush(bank, idx)
    ref = selective_flush_ref(bank, idx)
    np.testing.assert_array_equal(np.asarray(out.astype(jnp.float32)),
                                  np.asarray(ref.astype(jnp.float32)))


def test_selective_apply_roundtrip():
    bank = jnp.asarray(RNG.normal(size=(32, 64)).astype(np.float32))
    idx = jnp.asarray(np.array([3, 7, -1, 30], np.int32))
    flushed = selective_flush(bank, idx)
    restored = selective_apply(jnp.zeros_like(bank), flushed, idx)
    for i in [3, 7, 30]:
        np.testing.assert_array_equal(np.asarray(restored[i]),
                                      np.asarray(bank[i]))
    assert float(jnp.abs(restored).sum()) == pytest.approx(
        float(jnp.abs(bank[jnp.asarray([3, 7, 30])]).sum()), rel=1e-6)


@pytest.mark.parametrize("shape", [(2, 7, 128), (1, 256), (3, 5, 11, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape).astype(np.float32)).astype(dtype)
    w = jnp.asarray(RNG.normal(size=shape[-1:]).astype(np.float32))
    out = rmsnorm(x, w, use_pallas=True)
    ref = rmsnorm_ref(x, w)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out.astype(jnp.float32)),
                               np.asarray(ref.astype(jnp.float32)),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,hq,hkv,s,d,causal",
                         [(1, 4, 4, 128, 64, True),
                          (2, 8, 2, 128, 64, True),
                          (1, 4, 1, 256, 128, False),
                          (2, 2, 2, 64, 32, True)])
def test_flash_attention_sweep(b, hq, hkv, s, d, causal):
    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out.astype(jnp.float32)),
                               np.asarray(ref.astype(jnp.float32)),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("b,hq,hkv,s,d", [(2, 4, 2, 512, 64),
                                          (1, 8, 8, 1024, 128),
                                          (3, 4, 1, 256, 32)])
def test_flash_decode_sweep(b, hq, hkv, s, d):
    q = jnp.asarray(RNG.normal(size=(b, hq, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    kv_len = jnp.asarray(RNG.integers(1, s + 1, size=b).astype(np.int32))
    out = flash_decode(q, k, v, kv_len, block_k=128)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("t,e,k", [(64, 16, 2), (100, 32, 8), (7, 8, 4)])
def test_topk_router_sweep(t, e, k):
    logits = jnp.asarray(RNG.normal(size=(t, e)).astype(np.float32))
    w, i = topk_router(logits, k, use_pallas=True)
    wr, ir = topk_router_ref(logits, k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# fused-turn megakernel (DESIGN.md §12): interpret=True vs jnp oracle
# --------------------------------------------------------------------------

def _plan_inputs(n, *, tie_every=3, seed=1):
    rng = np.random.default_rng(seed)
    # small-integer clocks force ties (the lex order's hard case)
    clocks = jnp.asarray((rng.integers(0, max(2, n // tie_every),
                                       size=n)).astype(np.float32))
    can_l = jnp.asarray(rng.random(n) < 0.6)
    can_r = jnp.asarray(rng.random(n) < 0.4)
    bound = jnp.asarray(rng.integers(1, 5, size=n).astype(np.float32))
    raddr = jnp.asarray(rng.integers(0, max(2, n // 4), size=n)
                        .astype(np.int32))
    return clocks, can_l, can_r, bound, raddr


@pytest.mark.parametrize("n", [8, 64])
@pytest.mark.parametrize("remote_cap", [True, False])
@pytest.mark.parametrize("fenced", [True, False])
def test_trip_plan_kernel_matches_ref(n, remote_cap, fenced):
    from repro.kernels.fused_turn.kernel import trip_plan_pallas
    from repro.kernels.fused_turn.ref import BIG, trip_plan_ref
    clocks, can_l, can_r, bound, raddr = _plan_inputs(n)
    horizon = jnp.float32(float(np.median(np.asarray(clocks)))) \
        if fenced else None
    want = trip_plan_ref(clocks, can_l, can_r, bound,
                         raddr if remote_cap else None, horizon)
    got = trip_plan_pallas(clocks, can_l, can_r, bound, raddr,
                           BIG if horizon is None else horizon,
                           remote_cap=remote_cap, interpret=True)
    np.testing.assert_array_equal(np.asarray(got.lmask),
                                  np.asarray(want.lmask))
    np.testing.assert_array_equal(np.asarray(got.rmask),
                                  np.asarray(want.rmask))
    assert int(got.wg) == int(want.wg)


def test_trip_plan_kernel_empty_candidates():
    """No capable lane: lmask/rmask all-False and wg falls to 0 (matching
    jnp.argmin over an all-BIG row)."""
    from repro.kernels.fused_turn.kernel import trip_plan_pallas
    from repro.kernels.fused_turn.ref import BIG
    n = 8
    z = jnp.zeros((n,), bool)
    got = trip_plan_pallas(jnp.arange(n, dtype=jnp.float32), z, z,
                           jnp.ones((n,), jnp.float32),
                           jnp.zeros((n,), jnp.int32), BIG,
                           remote_cap=True, interpret=True)
    assert not bool(jnp.any(got.lmask)) and not bool(jnp.any(got.rmask))
    assert int(got.wg) == 0


def test_trip_plan_serial_fallback_is_one_hot():
    """Batch empty via a tight horizon, first argmin lane local-capable:
    lmask must be exactly one_hot(wg) — the folded serial-local case."""
    from repro.kernels.fused_turn.kernel import trip_plan_pallas
    from repro.kernels.fused_turn.ref import trip_plan_ref
    clocks = jnp.asarray(np.array([5.0, 2.0, 7.0, 2.0], np.float32))
    can_l = jnp.asarray(np.array([True, True, True, True]))
    can_r = jnp.asarray(np.array([False, False, True, False]))
    bound = jnp.ones((4,), jnp.float32)
    horizon = jnp.float32(0.0)   # fences out every batch lane
    want = trip_plan_ref(clocks, can_l, can_r, bound, None, horizon)
    got = trip_plan_pallas(clocks, can_l, can_r, bound,
                           jnp.zeros((4,), jnp.int32), horizon,
                           remote_cap=False, interpret=True)
    np.testing.assert_array_equal(np.asarray(got.lmask),
                                  np.asarray(want.lmask))
    assert int(got.wg) == 1 and np.asarray(want.lmask).sum() == 1
    assert bool(want.lmask[1])


@pytest.mark.parametrize("nb,W", [(4, 16), (8, 40)])   # L=1 and ragged L=2
def test_plane_commit_kernel_matches_ref(nb, W):
    from repro.core import bitmask
    from repro.kernels.fused_turn.kernel import plane_commit_pallas
    from repro.kernels.fused_turn.ref import plane_commit_ref
    rng = np.random.default_rng(7)
    n, L = 6, (W + 31) // 32
    wv = jnp.asarray(rng.integers(0, 2**32, size=(n, nb, L), dtype=np.uint64)
                     .astype(np.uint32))
    wd = jnp.asarray(rng.integers(0, 2**32, size=(n, nb, L), dtype=np.uint64)
                     .astype(np.uint32))
    b = jnp.asarray(rng.integers(0, nb, size=n).astype(np.int32))
    o = jnp.asarray(rng.integers(0, W, size=n).astype(np.int32))
    sv = jnp.asarray(rng.random(n) < 0.7)
    sd = jnp.asarray(rng.random(n) < 0.5)
    want = plane_commit_ref(wv, wd, b, o, sv, sd)
    got = plane_commit_pallas(wv, wd, b, o, sv, sd, interpret=True)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # cross-check against the boolean-layout reference through unpack
    unpack = lambda p: np.asarray(bitmask.unpack(jnp.asarray(p), W))  # noqa: E731
    wvb = jnp.asarray(unpack(wv))
    wdb = jnp.asarray(unpack(wd))
    wantb = plane_commit_ref(wvb, wdb, b, o, sv, sd)
    np.testing.assert_array_equal(unpack(got[0]), np.asarray(wantb[0]))
    np.testing.assert_array_equal(unpack(got[1]), np.asarray(wantb[1]))
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(wantb[2]))
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(wantb[3]))


def test_plane_commit_load_shape_skips_dirty():
    """set_dirty=None (the b_load call shape) must leave wdirty untouched
    and still report the pre-op bits of BOTH planes."""
    from repro.kernels.fused_turn.ref import plane_commit_ref
    rng = np.random.default_rng(9)
    n, nb, L = 4, 4, 1
    wv = jnp.asarray(rng.integers(0, 2**32, size=(n, nb, L),
                                  dtype=np.uint64).astype(np.uint32))
    wd = jnp.asarray(rng.integers(0, 2**32, size=(n, nb, L),
                                  dtype=np.uint64).astype(np.uint32))
    b = jnp.asarray(np.array([0, 1, 2, 3], np.int32))
    o = jnp.asarray(np.array([0, 5, 13, 15], np.int32))
    sv = jnp.asarray(np.array([True, False, True, True]))
    wv2, wd2, wasv, wasd = plane_commit_ref(wv, wd, b, o, sv, None)
    np.testing.assert_array_equal(np.asarray(wd2), np.asarray(wd))
    lane = np.arange(n)
    w = np.asarray(o) >> 5
    bit = np.uint32(1) << (np.asarray(o) & 31)
    np.testing.assert_array_equal(
        np.asarray(wasv), (np.asarray(wv)[lane, np.asarray(b), w] & bit) != 0)
    np.testing.assert_array_equal(
        np.asarray(wasd), (np.asarray(wd)[lane, np.asarray(b), w] & bit) != 0)
