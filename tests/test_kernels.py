"""Per-kernel validation: shape/dtype sweeps, interpret=True vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.selective_flush import selective_flush, selective_apply
from repro.kernels.selective_flush.ref import (selective_flush_ref,
                                               selective_apply_ref)
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode import flash_decode
from repro.kernels.flash_decode.ref import decode_attention_ref
from repro.kernels.topk_router import topk_router
from repro.kernels.topk_router.ref import topk_router_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("nb,bs,nd", [(16, 128, 4), (64, 256, 16),
                                      (128, 512, 32), (8, 128, 8)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_selective_flush_sweep(nb, bs, nd, dtype):
    bank = jnp.asarray(RNG.normal(size=(nb, bs)).astype(np.float32)).astype(dtype)
    idx = jnp.asarray(RNG.integers(-1, nb, size=nd).astype(np.int32))
    out = selective_flush(bank, idx)
    ref = selective_flush_ref(bank, idx)
    np.testing.assert_array_equal(np.asarray(out.astype(jnp.float32)),
                                  np.asarray(ref.astype(jnp.float32)))


def test_selective_apply_roundtrip():
    bank = jnp.asarray(RNG.normal(size=(32, 64)).astype(np.float32))
    idx = jnp.asarray(np.array([3, 7, -1, 30], np.int32))
    flushed = selective_flush(bank, idx)
    restored = selective_apply(jnp.zeros_like(bank), flushed, idx)
    for i in [3, 7, 30]:
        np.testing.assert_array_equal(np.asarray(restored[i]),
                                      np.asarray(bank[i]))
    assert float(jnp.abs(restored).sum()) == pytest.approx(
        float(jnp.abs(bank[jnp.asarray([3, 7, 30])]).sum()), rel=1e-6)


@pytest.mark.parametrize("shape", [(2, 7, 128), (1, 256), (3, 5, 11, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jnp.asarray(RNG.normal(size=shape).astype(np.float32)).astype(dtype)
    w = jnp.asarray(RNG.normal(size=shape[-1:]).astype(np.float32))
    out = rmsnorm(x, w, use_pallas=True)
    ref = rmsnorm_ref(x, w)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out.astype(jnp.float32)),
                               np.asarray(ref.astype(jnp.float32)),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,hq,hkv,s,d,causal",
                         [(1, 4, 4, 128, 64, True),
                          (2, 8, 2, 128, 64, True),
                          (1, 4, 1, 256, 128, False),
                          (2, 2, 2, 64, 32, True)])
def test_flash_attention_sweep(b, hq, hkv, s, d, causal):
    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 2, 128, 64))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out.astype(jnp.float32)),
                               np.asarray(ref.astype(jnp.float32)),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("b,hq,hkv,s,d", [(2, 4, 2, 512, 64),
                                          (1, 8, 8, 1024, 128),
                                          (3, 4, 1, 256, 32)])
def test_flash_decode_sweep(b, hq, hkv, s, d):
    q = jnp.asarray(RNG.normal(size=(b, hq, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    kv_len = jnp.asarray(RNG.integers(1, s + 1, size=b).astype(np.int32))
    out = flash_decode(q, k, v, kv_len, block_k=128)
    ref = decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("t,e,k", [(64, 16, 2), (100, 32, 8), (7, 8, 4)])
def test_topk_router_sweep(t, e, k):
    logits = jnp.asarray(RNG.normal(size=(t, e)).astype(np.float32))
    w, i = topk_router(logits, k, use_pallas=True)
    wr, ir = topk_router_ref(logits, k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr),
                               rtol=1e-5, atol=1e-6)
