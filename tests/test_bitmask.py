"""Packed word-bitmask primitives vs the boolean reference (DESIGN.md §8).

The packed `uint32` planes replace boolean per-word metadata throughout
the protocol engine; these tests pin every primitive bitwise-equal to the
boolean array semantics it encodes — including across uint32 word
boundaries (W not divisible by 32) and the ragged-tail invariant (padding
bits stay zero).  Property tests need hypothesis (CI installs it); the
deterministic word-boundary cases below run everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitmask

jax.config.update("jax_platform_name", "cpu")

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI always has it
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements.txt)")

# widths straddling every boundary case: sub-word, exact word, word+1, multi
WIDTHS = (1, 7, 31, 32, 33, 64, 80)


@pytest.mark.parametrize("w", WIDTHS)
def test_pack_unpack_roundtrip(w):
    rng = np.random.default_rng(w)
    flags = jnp.asarray(rng.integers(0, 2, (3, w)).astype(bool))
    packed = bitmask.pack(flags)
    assert packed.shape == (3, bitmask.n_lanes(w))
    assert packed.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(bitmask.unpack(packed, w)),
                                  np.asarray(flags))


@pytest.mark.parametrize("w", WIDTHS)
def test_ragged_tail_padding_stays_zero(w):
    """Invariant: bits at offsets >= W are zero after pack and set_bit, so
    any_set/popcount never need a tail mask."""
    flags = jnp.ones((w,), bool)
    packed = bitmask.pack(flags)
    for o in range(w):
        packed = bitmask.set_bit(packed, jnp.int32(o))
    unused = bitmask.n_lanes(w) * 32 - w
    if unused:
        tail = int(np.asarray(packed)[-1])
        assert tail < (1 << (32 - unused))  # high `unused` bits clear
    assert int(bitmask.popcount(packed)) == w


@pytest.mark.parametrize("w", WIDTHS)
def test_set_clear_get_match_boolean_reference(w):
    rng = np.random.default_rng(100 + w)
    ref = np.zeros(w, bool)
    vec = bitmask.zeros((), w)
    for _ in range(40):
        o = int(rng.integers(0, w))
        op = int(rng.integers(0, 3))
        cond = bool(rng.integers(0, 2))
        if op == 0:
            ref[o] |= cond
            vec = bitmask.set_bit(vec, jnp.int32(o), cond)
        elif op == 1:
            ref[o] &= not cond
            vec = bitmask.clear_bit(vec, jnp.int32(o), cond)
        else:
            assert bool(bitmask.get_bit(vec, jnp.int32(o))) == ref[o]
    np.testing.assert_array_equal(np.asarray(bitmask.unpack(vec, w)), ref)
    assert int(bitmask.popcount(vec)) == int(ref.sum())
    assert bool(bitmask.any_set(vec)) == bool(ref.any())


def test_word_index_and_bit_conventions():
    """LSB-first, 32 bits per lane: offset o -> lane o//32, bit o%32."""
    assert int(bitmask.word_index(jnp.int32(0))) == 0
    assert int(bitmask.word_index(jnp.int32(31))) == 0
    assert int(bitmask.word_index(jnp.int32(32))) == 1
    assert int(bitmask.word_bit(jnp.int32(0))) == 1
    assert int(bitmask.word_bit(jnp.int32(31))) == 1 << 31
    assert int(bitmask.word_bit(jnp.int32(33))) == 2
    words = jnp.asarray([0b101, 1 << 31], jnp.uint32)
    assert bool(bitmask.test_word(words[0], jnp.int32(0)))
    assert not bool(bitmask.test_word(words[0], jnp.int32(1)))
    assert bool(bitmask.test_word(words[1], jnp.int32(31)))


def test_popcount_word_exhaustive_patterns():
    pats = jnp.asarray([0, 1, 0xFFFFFFFF, 0xAAAAAAAA, 0x80000000, 0x7],
                       jnp.uint32)
    got = [int(x) for x in np.asarray(bitmask.popcount_word(pats))]
    assert got == [0, 1, 32, 16, 1, 3]


if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(st.integers(1, 80), st.integers(0, 2**32 - 1))
    def test_pack_matches_reference_random(w, seed):
        rng = np.random.default_rng(seed)
        flags = rng.integers(0, 2, w).astype(bool)
        packed = bitmask.pack(jnp.asarray(flags))
        # independent bit-weight reference
        want = np.zeros(bitmask.n_lanes(w), np.uint32)
        for o in range(w):
            if flags[o]:
                want[o // 32] |= np.uint32(1 << (o % 32))
        np.testing.assert_array_equal(np.asarray(packed), want)
        np.testing.assert_array_equal(
            np.asarray(bitmask.unpack(packed, w)), flags)

    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(st.integers(2, 70),
           st.lists(st.tuples(st.integers(0, 2), st.integers(0, 69),
                              st.booleans()), max_size=50))
    def test_op_soup_matches_boolean_reference(w, ops):
        """Random set/clear soup: the packed vector and a plain boolean
        array must agree after every op, popcount and any_set included —
        the exact obligations the wvalid/wdirty planes place on the
        layout."""
        ref = np.zeros(w, bool)
        vec = bitmask.zeros((), w)
        for op, o, cond in ops:
            o = o % w
            if op == 0:
                ref[o] |= cond
                vec = bitmask.set_bit(vec, jnp.int32(o), cond)
            elif op == 1:
                ref[o] &= not cond
                vec = bitmask.clear_bit(vec, jnp.int32(o), cond)
            else:
                assert bool(bitmask.get_bit(vec, jnp.int32(o))) == ref[o]
            assert int(bitmask.popcount(vec)) == int(ref.sum())
            assert bool(bitmask.any_set(vec)) == bool(ref.any())
        np.testing.assert_array_equal(np.asarray(bitmask.unpack(vec, w)),
                                      ref)
