"""Observability subsystem tests (ISSUE 7 acceptance).

Contracts:

1. **percentile bracketing** (hypothesis) — for any sample set, the
   log2-bucketed histogram's `percentile_bounds(q)` brackets the exact
   numpy quantile: lo <= quantile < hi (or hi infinite, the clamp
   bucket), and `percentile_upper` never under-reports a finite bound.
2. **ring overflow** (hypothesis) — any masked append sequence keeps
   the NEWEST `cap` events in order, reports the exact dropped count,
   and never corrupts neighbouring slots (decode equals the host-side
   reference event list).
3. **zero-op disablement** — every record_* helper on a cap-0 trace
   returns its input object untouched (Python `is`, the compiled-
   program-identity argument in DESIGN.md §11).
4. **export structure** — decode/chrome_trace produce Perfetto-loadable
   event objects (metadata + X spans on agent tracks + scheduler
   instants) and text_report renders from the JSON alone.
5. **bench regression gate** — benchmarks/compare.py exits 0 on an
   identical pair, nonzero on a regressed fixture (makespan, p99,
   check_ok flip, srsp ratio drop), 0 again under --advisory; the
   check_smoke structural gate passes a well-formed v6 doc and fails
   a v5 one.
"""
import importlib.util
import json
import math
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import export, metrics, trace as T

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI always has it
    HAVE_HYPOTHESIS = False

BENCH = pathlib.Path(__file__).resolve().parent.parent / "benchmarks"


def _load(modname):
    spec = importlib.util.spec_from_file_location(modname,
                                                  BENCH / f"{modname}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


compare = _load("compare")
check_smoke = _load("check_smoke")


# --------------------------------------------------------------------------
# 1. percentile bracketing
# --------------------------------------------------------------------------

def _assert_brackets(samples, q):
    x = np.asarray(samples, np.float32)          # bucketing is f32-exact
    hist = np.bincount(np.asarray(metrics.bucket_index(jnp.asarray(x))),
                       minlength=metrics.N_BUCKETS)
    lo, hi = metrics.percentile_bounds(hist, q)
    exact = float(np.quantile(x.astype(np.float64), q))
    assert lo <= exact < hi, (lo, exact, hi)       # inf hi trivially holds
    upper = metrics.percentile_upper(hist, q)
    if math.isinf(hi):
        assert upper == lo                         # clamp: lower bound
    else:
        assert upper == hi and exact < upper       # never an underestimate


def test_percentiles_bracket_fixed_samples():
    rng = np.random.default_rng(11)
    for q in (0.5, 0.95, 0.99):
        _assert_brackets(rng.lognormal(3.0, 2.0, 500), q)
        _assert_brackets([0.0], q)
        _assert_brackets([7.0, 7.0, 7.0], q)
        _assert_brackets(np.arange(100, dtype=np.float64), q)


def test_bucket_edges_are_exact():
    # a sample exactly on a power-of-two edge goes UP (half-open buckets)
    for k in range(1, 20):
        v = float(2 ** k)
        assert int(metrics.bucket_index(jnp.float32(v))) == k + 1
        assert metrics.bucket_lo(k + 1) == v
    assert int(metrics.bucket_index(jnp.float32(0.0))) == 0
    assert int(metrics.bucket_index(jnp.float32(0.5))) == 0
    assert math.isinf(metrics.bucket_hi(metrics.N_BUCKETS - 1))


def test_percentiles_of_empty_and_single():
    assert metrics.percentile_bounds(np.zeros(metrics.N_BUCKETS), 0.99) \
        == (0.0, 0.0)
    h = np.zeros(metrics.N_BUCKETS, np.int64)
    h[3] = 1                                     # one sample in [4, 8)
    assert metrics.percentile_bounds(h, 0.5) == (4.0, 8.0)
    assert metrics.summarize(h) == {"count": 1, "p50": 8.0, "p95": 8.0,
                                    "p99": 8.0}


# --------------------------------------------------------------------------
# 2. ring overflow
# --------------------------------------------------------------------------

def _check_ring(cap, steps):
    n = 3
    tl = T.make(cap, n)
    want = []                                    # host-side reference
    for i, mask in enumerate(steps):
        m = jnp.asarray(mask)
        tl = T._append(tl, m,
                       clock=jnp.full((n,), float(i), jnp.float32),
                       agent=jnp.arange(n, dtype=jnp.int32),
                       kind=T.LOAD, scope=1,
                       addr=jnp.arange(n, dtype=jnp.int32) + 100 * i,
                       cycles=1.0, outcome=T.OC_HIT)
        want += [(float(i), a, a + 100 * i) for a in range(n) if mask[a]]
    total = len(want)
    assert int(tl.head) == total
    dec = export.decode(tl)
    assert dec["dropped"] == max(total - cap, 0) == T.dropped(tl)
    assert dec["count"] == min(total, cap)
    kept = want[-dec["count"]:] if dec["count"] else []
    got = list(zip(dec["events"]["clock"].tolist(),
                   dec["events"]["agent"].tolist(),
                   dec["events"]["addr"].tolist()))
    assert got == kept                           # newest `cap`, oldest-first
    # nothing outside the valid region leaked into the decode
    assert all(int(k) == T.LOAD for k in dec["events"]["kind"])


def test_ring_overflow_fixed_sequences():
    full = [True] * 3
    _check_ring(4, [])                           # empty log decodes empty
    _check_ring(4, [full])                       # partial fill
    _check_ring(4, [full, full])                 # wraps by 2
    _check_ring(1, [full, [False, True, False]])  # cap 1 keeps only newest
    _check_ring(5, [[True, False, True]] * 4)    # masked lanes + wrap


# --------------------------------------------------------------------------
# 3. zero-op disablement
# --------------------------------------------------------------------------

def test_disabled_trace_is_python_identity():
    from repro.core import protocol as P
    cfg = P.ProtoConfig(n_caches=4, n_words=256)
    st_ = T.strip(P.make_store(cfg))
    assert not T.enabled(st_.trace) and T.capacity(st_.trace) == 0
    mask = jnp.asarray([True, False, True, False])
    addrs = jnp.zeros((4,), jnp.int32)
    assert T.record_op(st_, mask, T.ACQUIRE, 1, addrs,
                       st_.counters.cycles, T.OC_PROBE) is st_
    assert T.record_event(st_, mask, T.CHURN, 1) is st_
    assert T.record_turn(st_, st_.counters.cycles) is st_
    assert T.summary(st_) == {"latency_p50": None, "latency_p95": None,
                              "latency_p99": None, "latency_turns": 0,
                              "trace_events": 0, "trace_dropped": 0}


# --------------------------------------------------------------------------
# 4. export structure
# --------------------------------------------------------------------------

def _tiny_traced_store():
    from repro.core import ops as O
    from repro.core import protocol as P
    cfg = P.ProtoConfig(n_caches=4, n_words=256)
    st_ = T.with_trace(P.make_store(cfg), 64)
    proto = P.get_protocol("srsp")
    hot = jnp.arange(4) == 1
    st_, _ = O.acquire(proto, cfg, st_, hot, jnp.full((4,), 16, jnp.int32),
                       0, 1, scope=O.REMOTE)
    st_ = O.release(proto, cfg, st_, hot, jnp.full((4,), 16, jnp.int32),
                    7, scope=O.REMOTE)
    st_ = T.record_event(st_, hot, T.CHURN, 1)   # a crash instant
    return cfg, st_


def test_chrome_trace_structure(tmp_path):
    _, st_ = _tiny_traced_store()
    assert int(st_.trace.head) == 3
    path = tmp_path / "trace.json"
    doc = export.write_trace(str(path), st_, label="unit",
                             stragglers=[{"cell": "c", "wall_s": 1.0}])
    with open(path) as f:
        assert json.load(f) == doc               # round-trips through JSON
    ev = doc["traceEvents"]
    names = {e["name"] for e in ev if e["ph"] == "M"}
    assert "process_name" in names and "thread_name" in names
    spans = [e for e in ev if e["ph"] == "X"]
    assert len(spans) == 2                       # acquire + release
    assert all(e["tid"] == 1 and e["dur"] > 0 for e in spans)
    assert {e["cat"] for e in spans} == {"acquire", "release"}
    inst = [e for e in ev if e["ph"] == "i"]
    # churn instant on the scheduler track + the straggler marker
    assert any(e["tid"] == export.SCHED_TID and "churn:crash" in e["name"]
               for e in inst)
    assert any("straggler" in e["name"] for e in inst)
    meta = doc["srsp"]
    assert meta["events"] == 3 and meta["dropped"] == 0
    assert meta["kinds"] == {"acquire": 1, "release": 1, "churn": 1}
    rep = export.text_report(doc)
    assert "sRSP trace report: unit" in rep and "2 spans" in rep


def test_report_cli_reads_exported_json(tmp_path, capsys):
    from repro.obs import report
    _, st_ = _tiny_traced_store()
    path = tmp_path / "trace.json"
    export.write_trace(str(path), st_, label="cli")
    assert report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "sRSP trace report: cli" in out


# --------------------------------------------------------------------------
# 5. bench regression gate
# --------------------------------------------------------------------------

def _bench_doc(makespan=1000.0, p99=64.0, check_ok=True, ratio=1.5):
    return {
        "schema_version": 6,
        "runs": [{"workload": "worksteal", "scenario": "srsp",
                  "n_agents": 16, "engine": "batched",
                  "makespan": makespan, "check_ok": check_ok,
                  "latency_p50": 8.0, "latency_p95": 32.0,
                  "latency_p99": p99, "latency_turns": 100,
                  "trace_events": 0, "trace_dropped": 0}],
        "comparisons": {"pc16": {"srsp_vs_baseline": ratio,
                                 "completes_under_crash": True,
                                 "lost_updates": 0}},
    }


def _gate(base, new, *extra, tmp_path):
    bp, np_ = tmp_path / "base.json", tmp_path / "new.json"
    bp.write_text(json.dumps(base))
    np_.write_text(json.dumps(new))
    return compare.main([str(bp), str(np_), *extra])


def test_compare_identity_is_clean(tmp_path):
    assert _gate(_bench_doc(), _bench_doc(), tmp_path=tmp_path) == 0


@pytest.mark.parametrize("regressed", [
    dict(makespan=1100.0),          # +10% makespan
    dict(p99=512.0),                # p99 blow-up
    dict(check_ok=False),           # correctness flip
    dict(ratio=1.2),                # srsp lost ground vs baseline
])
def test_compare_flags_regressions(tmp_path, regressed):
    assert _gate(_bench_doc(), _bench_doc(**regressed),
                 tmp_path=tmp_path) == 1


def test_compare_advisory_reports_but_passes(tmp_path, capsys):
    assert _gate(_bench_doc(), _bench_doc(makespan=2000.0), "--advisory",
                 tmp_path=tmp_path) == 0
    assert "REGRESSION" in capsys.readouterr().out


def test_compare_tolerates_missing_latency_and_new_cells(tmp_path):
    base = _bench_doc()
    new = _bench_doc()
    new["runs"][0]["latency_p99"] = None         # trace-off candidate
    new["runs"].append(dict(new["runs"][0], workload="kv_directory",
                            latency_p99=None))   # new cell, no baseline
    assert _gate(base, new, tmp_path=tmp_path) == 0


def test_compare_improvements_are_not_failures(tmp_path):
    assert _gate(_bench_doc(), _bench_doc(makespan=800.0, p99=32.0,
                                          ratio=2.0),
                 tmp_path=tmp_path) == 0


def test_check_smoke_rejects_old_schema_accepts_v8():
    v8 = _bench_doc()
    v8.update(schema_version=8, kernel_mode="ref",
              remote_batch_ab=[{"check_ok": True}],
              trace={"enabled": False, "capacity": 0, "file": None,
                     "cell": None},
              stragglers=[])
    v8["runs"][0].update(api="scoped", remote_batch=True, churn_events=1,
                         recovered=1, lost_updates=0, kernel_mode="ref",
                         offered_load=None, completed=None, zipf_s=None,
                         burstiness=None, latency_source="turns")
    # v7 fused twin (same makespan) + v8 trace-driven kv_serving cell
    v8["runs"].append(dict(v8["runs"][0], engine="fused", churn_events=0))
    v8["runs"].append(dict(v8["runs"][0], workload="kv_serving",
                           churn_events=0, offered_load=96, completed=96,
                           zipf_s=1.1, burstiness=1.0,
                           latency_source="requests"))
    assert check_smoke.check(v8, expect_trace=False) == []
    old = json.loads(json.dumps(v8))
    old["schema_version"] = 7
    del old["runs"][0]["latency_p99"]
    del old["runs"][2]["offered_load"]
    fails = check_smoke.check(old, expect_trace=False)
    assert any("schema_version" in f for f in fails)
    assert any("latency columns" in f for f in fails)
    assert any("traffic columns" in f for f in fails)
    # a kv_serving cell that silently drops requests must be flagged
    lossy = json.loads(json.dumps(v8))
    lossy["runs"][2]["completed"] = 40
    assert any("dropped requests" in f
               for f in check_smoke.check(lossy, expect_trace=False))
    # --expect-trace on an untraced doc must fail loudly
    assert any("tracing was off" in f
               for f in check_smoke.check(v8, expect_trace=True))


# --------------------------------------------------------------------------
# hypothesis property sweeps (CI installs hypothesis; deterministic
# versions of both contracts above run everywhere)
# --------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.floats(min_value=0.0, max_value=1e7,
                              allow_nan=False),
                    min_size=1, max_size=200),
           st.sampled_from([0.5, 0.9, 0.95, 0.99]))
    def test_bucketed_percentiles_bracket_exact_quantiles(samples, q):
        _assert_brackets(samples, q)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(min_value=1, max_value=7),
           st.lists(st.lists(st.booleans(), min_size=3, max_size=3),
                    min_size=0, max_size=12))
    def test_ring_overflow_drops_oldest_never_corrupts(cap, steps):
        _check_ring(cap, steps)
