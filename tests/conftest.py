"""Shared pytest config.  NOTE: deliberately no XLA_FLAGS here — smoke tests
and benches must see the single real device; only launch/dryrun.py forces
512 host devices (and subprocess tests force their own counts)."""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Large compiled programs (worksteal sims, model stacks) accumulate
    LLVM JIT memory; drop them when a module finishes."""
    yield
    jax.clear_caches()
