"""Pluggable workload subsystem tests (ISSUE 2 acceptance).

Three contracts per registered workload:

1. **serial-vs-batched equivalence** — the batched scheduler's commute/
   fence rules must reproduce the serial reference bitwise (every state
   leaf, counters included), extending the worksteal equivalence suite's
   pattern (tests/test_engine_equivalence.py) to the new specs.
2. **self-check soundness** — each workload's consistency check is green
   under the correct protocols (srsp/rsp/baseline).
3. **self-check power** — a deliberately weakened protocol (remote
   acquire skipping promotion — the bug class sRSP exists to prevent)
   must be CAUGHT by every workload's check, and scope_only (local-scope
   remote ops, the paper's staleness demo) must be caught by every
   workload with remote turns.

Plus the vmapped many-replica runner the sweep uses: every lane of
`run_batched_many` must equal its solo `run_batched` run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import workloads
from repro.core import protocol as P
from repro.core import tables
from repro.obs import trace as T
from repro.workloads import faults, harness

NEW_WORKLOADS = ["producer_consumer", "reader_lock", "kv_directory"]
N_AGENTS = 4
SEED = 3


def _run(name, scenario, engine, seed=SEED, proto=None):
    """Fresh state per run: harness entry points donate their input."""
    b = workloads.get(name).build(scenario, N_AGENTS, seed=seed, proto=proto)
    final = harness.runner(engine)(b.wl, b.state, *b.ops)
    return final, b.check


def _assert_bitwise_equal(a, b, ctx):
    # trace leaves are stripped: serial and batched engines issue the same
    # ops at the same costs but in different calls, so event ORDER differs
    # by design (the strip-equality contract lives in
    # tests/test_engine_equivalence.py::test_trace_on_preserves_results)
    a, b = T.strip(a), T.strip(b)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=str(ctx))


@pytest.mark.parametrize("name", NEW_WORKLOADS)
def test_serial_batched_bitwise_equivalent(name):
    ser, check = _run(name, "srsp", "serial")
    bat, _ = _run(name, "srsp", "batched")
    _assert_bitwise_equal(ser, bat, (name, "srsp"))
    assert check(ser)["ok"], name
    jax.clear_caches()


@pytest.mark.slow
@pytest.mark.parametrize("name", NEW_WORKLOADS)
@pytest.mark.parametrize("scenario", ["rsp", "baseline"])
def test_serial_batched_equivalent_other_scenarios(name, scenario):
    ser, check = _run(name, scenario, "serial")
    bat, _ = _run(name, scenario, "batched")
    _assert_bitwise_equal(ser, bat, (name, scenario))
    assert check(ser)["ok"], (name, scenario)
    jax.clear_caches()


@pytest.mark.parametrize("name", NEW_WORKLOADS)
def test_zero_churn_elastic_pin(name):
    """ISSUE 6 acceptance: wrapping a bench in the elastic alive-set
    machinery with an EMPTY churn schedule must be bitwise invisible —
    same final state as the plain batched engine, every leaf."""
    b = workloads.get(name).build("srsp", N_AGENTS, seed=SEED)
    ref = harness.run_batched(b.wl, b.state, *b.ops)
    b2 = workloads.get(name).build("srsp", N_AGENTS, seed=SEED)
    eb = harness.make_elastic(b2)
    fin = harness.run_batched_elastic(eb.wl, eb.state, *eb.ops)
    _assert_bitwise_equal(ref, fin.s, (name, "zero-churn"))
    assert bool(np.all(np.asarray(fin.alive))), name
    jax.clear_caches()


@pytest.mark.parametrize("name", NEW_WORKLOADS)
def test_weakened_protocol_is_caught(name):
    """Remote acquire without promotion (faults.no_promotion) leaves the
    owners' released writes stranded in their L1s; every workload's
    self-check must flag the resulting stale reads."""
    broken = faults.no_promotion(P.get_protocol("srsp"))
    final, check = _run(name, "srsp", "batched", proto=broken)
    res = check(final)
    assert not res["ok"], (name, res)
    assert res["check_fails"] > 0, (name, res)
    jax.clear_caches()


@pytest.mark.parametrize("name", ["kv_directory", "reader_lock"])
def test_tiny_pa_geometry_still_correct(name):
    """Stress the silent-LRU PA eviction (DESIGN.md §8): with a 1×2 PA
    table — two entries total — the self-checks must STAY green, because
    the promotion record a local acquire needs is by construction the
    most recently remotely-released (hottest) entry of its set, and the
    probe re-inserts it on every remote acquire."""
    geom = tables.TableGeometry(sets=1, ways=2)
    b = workloads.get(name).build("srsp", N_AGENTS, seed=SEED, pa_tbl=geom)
    final = harness.run_batched(b.wl, b.state, *b.ops)
    res = b.check(final)
    assert res["ok"], (name, res)
    jax.clear_caches()


@pytest.mark.slow
def test_weakened_protocol_caught_by_worksteal_too():
    final, check = _run("worksteal", "srsp", "batched",
                        proto=faults.no_promotion(P.get_protocol("srsp")))
    assert not check(final)["ok"]
    jax.clear_caches()


@pytest.mark.slow
@pytest.mark.parametrize("name", NEW_WORKLOADS)
def test_scope_only_staleness_is_caught(name):
    """Local-scope sync for remote ops is the paper's staleness demo —
    the checks must see it."""
    final, check = _run(name, "scope_only", "batched")
    assert not check(final)["ok"], name
    jax.clear_caches()


def test_worksteal_bench_contract():
    """The first registered workload drives through the same contract."""
    b = workloads.get("worksteal").build("srsp", N_AGENTS, seed=0)
    final = harness.run_batched(b.wl, b.state, *b.ops)
    res = b.check(final)
    assert res["ok"], res
    assert float(final.store.counters.steals) > 0  # stealing really happened
    jax.clear_caches()


def test_vmapped_replicas_match_solo_runs():
    m = workloads.get("kv_directory")
    b = m.build("srsp", N_AGENTS, seed=0)
    states = jax.vmap(lambda s: m.init_state(b.wl, s))(jnp.arange(2))
    outs = harness.run_batched_many(b.wl, states)
    for k in range(2):
        solo = m.build("srsp", N_AGENTS, seed=k)
        ref = harness.run_batched(solo.wl, solo.state)
        lane = jax.tree.map(lambda x: x[k], outs)
        # rounds may drift (finished replicas idle while stragglers run);
        # everything observable must match bitwise
        _assert_bitwise_equal(ref._replace(rounds=jnp.int32(0)),
                              lane._replace(rounds=jnp.int32(0)), k)
        assert m.self_check(solo.wl, lane)["ok"]
    jax.clear_caches()


def test_registry_lists_all_workloads():
    names = workloads.available()
    assert set(NEW_WORKLOADS) <= set(names)
    assert "worksteal" in names
    assert "producer_consumer_mc" in names   # the multi-consumer variant
    for n in names:
        m = workloads.get(n)
        assert hasattr(m, "build") and hasattr(m, "VMAPPABLE")
