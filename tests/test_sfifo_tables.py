"""Unit + hypothesis property tests for the sFIFO / LR-TBL / PA-TBL
hardware structures (paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import sfifo, tables

jax.config.update("jax_platform_name", "cpu")


def test_push_pos_monotone():
    f = sfifo.make(4)
    f, ev, p0 = sfifo.push(f, 1)
    f, ev, p1 = sfifo.push(f, 2, force_tail=True)
    assert int(p1) > int(p0)
    assert int(ev) == -1


def test_write_combining_no_duplicate():
    f = sfifo.make(4)
    f, _, _ = sfifo.push(f, 7)
    f, _, _ = sfifo.push(f, 7)
    assert int(sfifo.size(f)) == 1


def test_release_moves_to_tail():
    f = sfifo.make(4)
    f, _, _ = sfifo.push(f, 1)
    f, _, _ = sfifo.push(f, 2)
    f, _, pos = sfifo.push(f, 1, force_tail=True)  # re-release block 1
    f, drained, count = sfifo.drain_upto(f, pos)
    d = np.asarray(drained)
    assert int(count) == 2
    # FIFO order: 2 (older) then 1 (moved to tail)
    assert list(d[:2]) == [2, 1]


def test_capacity_eviction_returns_oldest():
    f = sfifo.make(2)
    f, _, _ = sfifo.push(f, 1)
    f, _, _ = sfifo.push(f, 2)
    f, ev, _ = sfifo.push(f, 3)
    assert int(ev) == 1  # oldest written back


def test_drain_upto_prefix_only():
    f = sfifo.make(8)
    poss = []
    for a in [10, 11, 12, 13]:
        f, _, p = sfifo.push(f, a)
        poss.append(p)
    f, drained, count = sfifo.drain_upto(f, poss[1])
    assert int(count) == 2
    assert list(np.asarray(drained)[:2]) == [10, 11]
    assert int(sfifo.size(f)) == 2


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 15), st.booleans()), max_size=40))
def test_fifo_matches_python_model(ops):
    """Random pushes (w/ and w/o force_tail) then drain_all == python deque."""
    cap = 6
    f = sfifo.make(cap)
    model = []  # list of addrs in FIFO order
    for addr, force in ops:
        if addr in model:
            if force:
                model.remove(addr)
                model.append(addr)
        else:
            if len(model) == cap:
                model.pop(0)
            model.append(addr)
        f, _, _ = sfifo.push(f, addr, force_tail=force)
    f, drained, count = sfifo.drain_all(f)
    got = [int(x) for x in np.asarray(drained)[:int(count)]]
    assert got == model


def test_lr_insert_lookup_update():
    t = tables.lr_make(4)
    t, ea, ep = tables.lr_insert(t, 5, 100)
    assert int(tables.lr_lookup(t, 5)) == 100
    t, _, _ = tables.lr_insert(t, 5, 200)  # update in place
    assert int(tables.lr_lookup(t, 5)) == 200
    assert int(tables.lr_lookup(t, 6)) == -1


def test_lr_eviction_returns_victim():
    t = tables.lr_make(2)
    t, _, _ = tables.lr_insert(t, 1, 10)
    t, _, _ = tables.lr_insert(t, 2, 20)
    t, ea, ep = tables.lr_insert(t, 3, 30)
    assert (int(ea), int(ep)) == (1, 10)  # FIFO eviction
    assert int(tables.lr_lookup(t, 3)) == 30


def test_pa_overflow_sets_promote_all():
    t = tables.pa_make(2)
    t = tables.pa_insert(t, 1)
    t = tables.pa_insert(t, 2)
    assert not bool(t.promote_all)
    t = tables.pa_insert(t, 3)
    assert bool(t.promote_all)
    assert bool(tables.pa_contains(t, 99))  # everything promotes now
    t = tables.pa_clear(t)
    assert not bool(tables.pa_contains(t, 1))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 9), max_size=20))
def test_pa_contains_is_sound(addrs):
    """pa_contains never returns False for an inserted address (conservative
    overflow semantics — required for memory-model soundness)."""
    t = tables.pa_make(4)
    for a in addrs:
        t = tables.pa_insert(t, a)
    for a in addrs:
        assert bool(tables.pa_contains(t, a))
