"""Unit + hypothesis property tests for the sFIFO / LR-TBL / PA-TBL
hardware structures (paper §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import sfifo, tables

jax.config.update("jax_platform_name", "cpu")


def test_push_pos_monotone():
    f = sfifo.make(4)
    f, ev, p0 = sfifo.push(f, 1)
    f, ev, p1 = sfifo.push(f, 2, force_tail=True)
    assert int(p1) > int(p0)
    assert int(ev) == -1


def test_write_combining_no_duplicate():
    f = sfifo.make(4)
    f, _, _ = sfifo.push(f, 7)
    f, _, _ = sfifo.push(f, 7)
    assert int(sfifo.size(f)) == 1


def test_release_moves_to_tail():
    f = sfifo.make(4)
    f, _, _ = sfifo.push(f, 1)
    f, _, _ = sfifo.push(f, 2)
    f, _, pos = sfifo.push(f, 1, force_tail=True)  # re-release block 1
    f, drained, count = sfifo.drain_upto(f, pos)
    d = np.asarray(drained)
    assert int(count) == 2
    # FIFO order: 2 (older) then 1 (moved to tail)
    assert list(d[:2]) == [2, 1]


def test_capacity_eviction_returns_oldest():
    f = sfifo.make(2)
    f, _, _ = sfifo.push(f, 1)
    f, _, _ = sfifo.push(f, 2)
    f, ev, _ = sfifo.push(f, 3)
    assert int(ev) == 1  # oldest written back


def test_drain_upto_prefix_only():
    f = sfifo.make(8)
    poss = []
    for a in [10, 11, 12, 13]:
        f, _, p = sfifo.push(f, a)
        poss.append(p)
    f, drained, count = sfifo.drain_upto(f, poss[1])
    assert int(count) == 2
    assert list(np.asarray(drained)[:2]) == [10, 11]
    assert int(sfifo.size(f)) == 2


if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 15), st.booleans()),
                    max_size=40))
    def test_fifo_matches_python_model(ops):
        """Random pushes (w/ and w/o force_tail) then drain_all == python
        deque."""
        cap = 6
        f = sfifo.make(cap)
        model = []  # list of addrs in FIFO order
        for addr, force in ops:
            if addr in model:
                if force:
                    model.remove(addr)
                    model.append(addr)
            else:
                if len(model) == cap:
                    model.pop(0)
                model.append(addr)
            f, _, _ = sfifo.push(f, addr, force_tail=force)
        f, drained, count = sfifo.drain_all(f)
        got = [int(x) for x in np.asarray(drained)[:int(count)]]
        assert got == model


def test_lr_insert_lookup_update():
    t = tables.lr_make(4)
    t, ea, ep = tables.lr_insert(t, 5, 100)
    assert int(tables.lr_lookup(t, 5)) == 100
    t, _, _ = tables.lr_insert(t, 5, 200)  # update in place
    assert int(tables.lr_lookup(t, 5)) == 200
    assert int(tables.lr_lookup(t, 6)) == -1


def test_lr_eviction_returns_victim():
    t = tables.lr_make(2)
    t, _, _ = tables.lr_insert(t, 1, 10)
    t, _, _ = tables.lr_insert(t, 2, 20)
    t, ea, ep = tables.lr_insert(t, 3, 30)
    assert (int(ea), int(ep)) == (1, 10)  # LRU == FIFO when never re-touched
    assert int(tables.lr_lookup(t, 3)) == 30


def test_lr_reinsert_refreshes_age():
    """Per-address aging: re-recording a release protects the entry — the
    LRU victim is the *coldest* address, not the first-inserted one."""
    t = tables.lr_make(2)
    t, _, _ = tables.lr_insert(t, 1, 10)
    t, _, _ = tables.lr_insert(t, 2, 20)
    t, _, _ = tables.lr_insert(t, 1, 11)      # refresh addr 1
    t, ea, ep = tables.lr_insert(t, 3, 30)
    assert (int(ea), int(ep)) == (2, 20)      # 2 is now the coldest
    assert int(tables.lr_lookup(t, 1)) == 11


def test_lr_sets_isolate_eviction():
    """Set-associative: pressure on one set never evicts another set's
    entries (set index = block id (addr>>4) mod sets)."""
    t = tables.lr_make(tables.TableGeometry(sets=2, ways=1))
    t, _, _ = tables.lr_insert(t, 0x10, 1)     # block 1 -> set 1
    t, ea, _ = tables.lr_insert(t, 0x20, 2)    # block 2 -> set 0
    assert int(ea) == -1                       # different set: no eviction
    t, ea, ep = tables.lr_insert(t, 0x30, 3)   # block 3 -> set 1: evicts 0x10
    assert (int(ea), int(ep)) == (0x10, 1)
    assert int(tables.lr_lookup(t, 0x20)) == 2


# ---------------------------------------------------------------------------
# PA-TBL — set-associative LRU replaces the sticky promote_all bit
# ---------------------------------------------------------------------------

def test_pa_overflow_stays_selective():
    """The directory-pressure pattern that used to trip sticky promote_all:
    more distinct one-shot addresses than capacity.  Now the coldest entry
    evicts and *unrelated* addresses still do NOT promote."""
    geom = tables.TableGeometry(sets=2, ways=2)
    t = tables.pa_make(geom)
    addrs = [0x10, 0x20, 0x30, 0x40, 0x50, 0x60, 0x70]  # > capacity 4
    for a in addrs:
        t = tables.pa_insert(t, a)
    # most-recently-inserted addresses are still recorded ...
    assert bool(tables.pa_contains(t, 0x70))
    assert bool(tables.pa_contains(t, 0x60))
    # ... and an address never inserted still does not promote (with the
    # old sticky bit this returned True forever after overflow)
    assert not bool(tables.pa_contains(t, 0x990))
    t = tables.pa_reset(t)
    assert not bool(tables.pa_contains(t, 0x70))


def test_pa_lru_eviction_and_refresh():
    """Aging: re-inserting (a lock remotely released again) refreshes the
    entry, so overflow evicts the cold address, not the hot one."""
    t = tables.pa_make(tables.TableGeometry(sets=1, ways=2))
    t = tables.pa_insert(t, 0x10)
    t = tables.pa_insert(t, 0x20)
    t = tables.pa_insert(t, 0x10)   # refresh
    t = tables.pa_insert(t, 0x30)   # evicts 0x20 (coldest)
    assert bool(tables.pa_contains(t, 0x10))
    assert bool(tables.pa_contains(t, 0x30))
    assert not bool(tables.pa_contains(t, 0x20))


def test_pa_probe_refreshes_on_hit():
    """LRU aging on probe: pa_probe returns the hit AND protects the probed
    entry from the next eviction."""
    t = tables.pa_make(tables.TableGeometry(sets=1, ways=2))
    t = tables.pa_insert(t, 0x10)
    t = tables.pa_insert(t, 0x20)
    t, hit = tables.pa_probe(t, 0x10)           # refresh by probe
    assert bool(hit)
    t, miss = tables.pa_probe(t, 0x990)
    assert not bool(miss)
    t = tables.pa_insert(t, 0x30)               # evicts 0x20, not probed 0x10
    assert bool(tables.pa_contains(t, 0x10))
    assert not bool(tables.pa_contains(t, 0x20))


def test_reset_derives_geometry_from_live_table():
    """pa_reset/lr_reset must rebuild from the live table, never default
    literals — a configured TableGeometry survives resets/invalidations."""
    geom = tables.TableGeometry(sets=4, ways=3)
    pa = tables.pa_insert(tables.pa_make(geom), 0x10)
    pa = tables.pa_reset(pa)
    assert pa.addrs.shape == (geom.sets, geom.ways)
    assert not bool(tables.pa_contains(pa, 0x10))
    lr, _, _ = tables.lr_insert(tables.lr_make(geom), 0x10, 1)
    lr = tables.lr_reset(lr)
    assert lr.addrs.shape == (geom.sets, geom.ways)
    assert int(tables.lr_lookup(lr, 0x10)) == -1


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(0, 9), max_size=20))
    def test_pa_contains_sound_within_capacity(addrs):
        """The `ways` most-recently-touched distinct addresses of any one
        set are ALWAYS resident (LRU order) — in particular nothing is
        silently dropped while a set has not overflowed."""
        geom = tables.TableGeometry(sets=2, ways=4)
        t = tables.pa_make(geom)
        for a in addrs:
            t = tables.pa_insert(t, a * 16)
        per_set = {}
        for a in addrs:  # replay: most-recent-distinct per set, newest first
            s = (a * 16 >> 4) % geom.sets
            lst = per_set.setdefault(s, [])
            if a * 16 in lst:
                lst.remove(a * 16)
            lst.insert(0, a * 16)
        for s, lst in per_set.items():
            for a in lst[:geom.ways]:
                assert bool(tables.pa_contains(t, a)), (addrs, s, a)
