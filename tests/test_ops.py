"""Scope-parametric ISA tests (ISSUE 4 acceptance).

Contracts:

1. **scoped dispatch == legacy paths** — `ops.acquire/release` with a
   one-hot mask and a static scope must be bitwise-equal to the legacy
   scalar op it replaced (`local_acquire`, `srsp_remote_acquire`,
   `global_acquire`, …), for every registered protocol, on every store
   leaf.  At workload level: each workload run through the scoped
   surface with the batched remote twins must equal the same run with
   the twins stripped (`faults.serialize_remote` — the legacy
   serialized-scalar path).  The REPRO_NO_PACK / REPRO_NO_DONATE
   metadata layouts are covered by the CI escape-hatch matrix running
   this whole file under each flag.
2. **disjoint-addr remote batch == serialized order** — a single
   batched remote op (acquire-only or release-only) over lanes with
   pairwise-distinct addresses and disjoint sharer sets is bitwise-equal
   to issuing the scalar op per lane in ascending order (DESIGN.md §9).
3. **deprecation shims** — the pre-redesign `owner_*`/`thief_*`
   Protocol attributes still work and emit DeprecationWarning exactly
   once per name.
4. **registry ergonomics** — unknown protocol/engine/scenario names
   raise with the list of registered names.

Plus the multi-consumer producer/consumer equivalence: co-scheduled
remote turns (a TRUE multi-lane remote batch) reproduce the serial
engine bitwise on every leaf except the PA-TBL age/content metadata,
where the batch is a documented cost-conservative superset (§9).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import workloads
from repro.core import ops as O
from repro.core import protocol as P
from repro.obs import trace as T
from repro.workloads import faults, harness

CFG = P.ProtoConfig(n_caches=4, n_words=256)


def _hot(cid):
    return jnp.arange(CFG.n_caches) == cid


def _fill(v):
    return jnp.full((CFG.n_caches,), v, jnp.int32)


def _assert_stores_equal(a, b, ctx):
    # trace stripped: the scoped surface records events the raw protocol
    # ops (and the serialized legacy path) don't, and event order differs
    # across engines — the trace contract has its own suite (test_obs.py,
    # test_engine_equivalence.py::test_trace_on_preserves_results)
    a, b = T.strip(a), T.strip(b)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=str(ctx))


def _seed_store():
    """A store with LR entries, PA entries and dirty data in play."""
    st = P.make_store(CFG)
    st, _ = P.store_word(CFG, st, 0, 17, 41)
    st, _ = P.store_word(CFG, st, 1, 49, 43)
    st = P.local_release(CFG, st, 0, 16, 7)    # LR entry: cache 0, addr 16
    st = P.local_release(CFG, st, 1, 48, 9)    # LR entry: cache 1, addr 48
    st, _ = P.store_word(CFG, st, 2, 130, 45)
    st = P.srsp_remote_release(CFG, st, 3, 64, 5)  # PA entries everywhere
    return st


# --------------------------------------------------------------------------
# 1. scoped dispatch == legacy scalar paths
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pname", ["srsp", "rsp", "global", "local"])
def test_scoped_dispatch_matches_legacy_scalar_ops(pname):
    """One-hot ops.acquire/release at each scope vs the scalar op table
    entry it routes to — bitwise, for every registered protocol."""
    proto = P.get_protocol(pname)
    scalar = {O.LOCAL: (proto.acquire_loc, proto.release_loc),
              O.REMOTE: (proto.acquire_rem, proto.release_rem),
              O.GLOBAL: (proto.acquire_glob, proto.release_glob)}
    for scope in O.SCOPES:
        sa = _seed_store()
        sb = _seed_store()
        acq, rel = scalar[scope]
        sa, old_a = acq(CFG, sa, 2, 16, 0, 1)
        sb, old_b = O.acquire(proto, CFG, sb, _hot(2), _fill(16), 0, 1,
                              scope=scope)
        np.testing.assert_array_equal(int(old_a), int(old_b[2]),
                                      err_msg=(pname, scope, "old"))
        sa = rel(CFG, sa, 2, 16, 0)
        sb = O.release(proto, CFG, sb, _hot(2), _fill(16), 0, scope=scope)
        _assert_stores_equal(sa, sb, (pname, scope))
    jax.clear_caches()


@pytest.mark.parametrize("name", ["producer_consumer", "reader_lock",
                                  "kv_directory", "worksteal"])
def test_workload_scoped_vs_serialized_remote_path(name):
    """Each workload through the batched remote twins vs through the
    stripped-capability protocol (the legacy serialized scalar path) —
    bitwise on every leaf, batched engine."""
    a = workloads.get(name).build("srsp", 4, seed=3)
    fa = harness.run_batched(a.wl, a.state, *a.ops)
    b = workloads.get(name).build(
        "srsp", 4, seed=3,
        proto=faults.serialize_remote(P.get_protocol("srsp")))
    fb = harness.run_batched(b.wl, b.state, *b.ops)
    _assert_stores_equal(fa, fb, name)
    assert a.check(fa)["ok"], name
    jax.clear_caches()


def test_mixed_scope_vector_dispatch():
    """A per-agent scope array carries one mixed-scope bundle; dispatch
    order is loc, glob, rem (documented), matching the manual calls."""
    proto = P.get_protocol("srsp")
    addrs = jnp.asarray([16, 96, 48, 128], jnp.int32)
    scope = jnp.asarray([O.LOCAL, O.LOCAL, O.REMOTE, O.GLOBAL], jnp.int32)
    active = jnp.ones((4,), bool)
    sa = _seed_store()
    sa, old_a = O.acquire(proto, CFG, sa, active, addrs, 0, 1, scope=scope)
    sb = _seed_store()
    loc = jnp.asarray([True, True, False, False])
    sb, old_l = proto.acquire_loc_b(CFG, sb, loc, addrs, _fill(0), _fill(1))
    glob = jnp.asarray([False, False, False, True])
    sb, old_g = proto.acquire_glob_b(CFG, sb, glob, addrs, _fill(0),
                                     _fill(1))
    rem = jnp.asarray([False, False, True, False])
    sb, old_r = proto.acquire_rem_b(CFG, sb, rem, addrs, _fill(0), _fill(1))
    # ops.acquire = scope dispatch + clock-stamped lease bookkeeping
    # (DESIGN.md §10); apply the same stamp to the manual reference
    sb = P.lease_stamp(sb, active, addrs)
    _assert_stores_equal(sa, sb, "mixed-scope")
    want = jnp.where(rem, old_r, jnp.where(glob, old_g, old_l))
    np.testing.assert_array_equal(np.asarray(old_a), np.asarray(want))
    jax.clear_caches()


def test_unknown_scope_raises():
    with pytest.raises(ValueError, match="unknown scope"):
        O.acquire(P.get_protocol("srsp"), CFG, _seed_store(),
                  _hot(0), _fill(0), 0, 1, scope=7)


# --------------------------------------------------------------------------
# 2. disjoint-addr remote batch == serialized remote order
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pname", ["srsp", "global"])
def test_disjoint_remote_acquire_batch_equals_serialized(pname):
    """Two issuers, distinct addrs, disjoint sharer sets: one batched
    remote acquire == the two scalar acquires in ascending lane order."""
    proto = P.get_protocol(pname)
    sa = _seed_store()
    sa, old2 = proto.acquire_rem(CFG, sa, 2, 16, 0, 1)
    sa, old3 = proto.acquire_rem(CFG, sa, 3, 48, 0, 1)
    sb = _seed_store()
    active = jnp.asarray([False, False, True, True])
    addrs = jnp.asarray([0, 0, 16, 48], jnp.int32)
    sb, old_b = proto.acquire_rem_b(CFG, sb, active, addrs, _fill(0),
                                    _fill(1))
    _assert_stores_equal(sa, sb, pname)
    assert int(old2) == int(old_b[2]) and int(old3) == int(old_b[3])
    jax.clear_caches()


@pytest.mark.parametrize("pname", ["srsp", "global"])
def test_disjoint_remote_release_batch_equals_serialized(pname):
    proto = P.get_protocol(pname)
    sa = _seed_store()
    sa = proto.release_rem(CFG, sa, 2, 16, 11)
    sa = proto.release_rem(CFG, sa, 3, 48, 13)
    sb = _seed_store()
    active = jnp.asarray([False, False, True, True])
    addrs = jnp.asarray([0, 0, 16, 48], jnp.int32)
    vals = jnp.asarray([0, 0, 11, 13], jnp.int32)
    sb = proto.release_rem_b(CFG, sb, active, addrs, vals)
    _assert_stores_equal(sa, sb, pname)
    jax.clear_caches()


def test_same_cu_remote_acquire_one_hot_equals_scalar():
    """The §4.2 same-CU fork (issuer holds its own LR entry) through the
    batched twin — the scalar op's lax.cond branch, mask-executed."""
    sa = _seed_store()
    sa, old_a = P.srsp_remote_acquire(CFG, sa, 0, 16, 7, 2)  # own LR entry
    sb = _seed_store()
    sb, old_b = P.srsp_remote_acquire_b(CFG, sb, _hot(0), _fill(16),
                                        _fill(7), _fill(2))
    _assert_stores_equal(sa, sb, "same-cu")
    assert int(old_a) == int(old_b[0])
    jax.clear_caches()


# --------------------------------------------------------------------------
# 3. deprecation shims
# --------------------------------------------------------------------------

def test_deprecation_shims_warn_exactly_once():
    proto = P.get_protocol("srsp")
    P._DEPRECATION_WARNED.discard("owner_acquire_b")
    with warnings.catch_warnings(record=True) as seen:
        warnings.simplefilter("always")
        assert proto.owner_acquire_b is proto.acquire_loc_b
        assert proto.owner_acquire_b is proto.acquire_loc_b  # second access
    dep = [w for w in seen if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, [str(w.message) for w in dep]
    assert "acquire_loc_b" in str(dep[0].message)


def test_deprecation_shims_route_to_scoped_table():
    proto = P.get_protocol("srsp")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert proto.owner_acquire is proto.acquire_loc
        assert proto.owner_release is proto.release_loc
        assert proto.thief_acquire is proto.acquire_rem
        assert proto.thief_release is proto.release_rem
        assert proto.owner_release_b is proto.release_loc_b


# --------------------------------------------------------------------------
# 4. registry ergonomics
# --------------------------------------------------------------------------

def test_unknown_names_raise_with_registered_list():
    with pytest.raises(KeyError, match="registered.*srsp"):
        P.get_protocol("nope")
    # registry misses stay catchable as ValueError too (the pre-registry
    # runner()/WorkStealSim checks raised ValueError)
    with pytest.raises(ValueError):
        harness.runner("nope")
    with pytest.raises(KeyError, match="registered.*srsp"):
        P.PROTOCOLS["nope"]
    with pytest.raises(KeyError, match="registered.*batched"):
        harness.runner("nope")
    with pytest.raises(KeyError, match="registered.*srsp"):
        harness.resolve_proto("nope")
    from repro.workloads import worksteal
    with pytest.raises(ValueError, match="registered.*srsp"):
        worksteal.WorkStealSim(worksteal.WSConfig(n_wgs=2), "nope")
    assert "srsp" in P.protocols()
    assert set(harness.engines()) == {
        "serial", "batched", "fused", "serial_elastic", "batched_elastic"}
    assert "baseline" in harness.scenarios()


def test_drain_all_sentinel_is_public():
    assert int(P.DRAIN_ALL) == int(P._DRAIN_ALL)
    st = _seed_store()
    st = harness.drain_all(CFG, st)
    assert not bool(np.asarray(P.wdirty_bool(st)).any())


def test_protocol_capability_declaration():
    assert P.get_protocol("srsp").remote_batchable
    assert P.get_protocol("global").remote_batchable
    assert P.get_protocol("local").remote_batchable
    assert not P.get_protocol("rsp").remote_batchable       # flush-all
    assert not faults.serialize_remote(
        P.get_protocol("srsp")).remote_batchable
    assert not faults.no_promotion(
        P.get_protocol("srsp")).remote_batchable


# --------------------------------------------------------------------------
# multi-consumer producer/consumer: TRUE co-scheduled remote batches
# --------------------------------------------------------------------------

def _pa_addr_sets(st):
    a = np.asarray(st.pa.addrs)
    return [set(int(x) for x in a[c].ravel() if x >= 0)
            for c in range(a.shape[0])]


def test_multi_consumer_serial_batched_equivalent():
    """Serial vs batched engines on producer_consumer_mc (srsp): the
    batched engine co-schedules disjoint drains.  Everything observable
    — counters, solutions, bookkeeping, self-check — is bitwise equal;
    the PA-TBL metadata is exempt: a co-scheduled batch permutes
    same-trip PA insertions, leaving a documented cost-conservative
    SUPERSET of the serial content (DESIGN.md §9)."""
    mod = workloads.get("producer_consumer_mc")
    a = mod.build("srsp", 8, seed=1)
    ser = harness.run_serial(a.wl, a.state, *a.ops)
    b = mod.build("srsp", 8, seed=1)
    bat = harness.run_batched(b.wl, b.state, *b.ops)
    _assert_stores_equal(ser._replace(store=ser.store._replace(pa=None)),
                         bat._replace(store=bat.store._replace(pa=None)),
                         "mc")
    for c, (sa, sb) in enumerate(zip(_pa_addr_sets(ser.store),
                                     _pa_addr_sets(bat.store))):
        assert sa <= sb, (c, sa, sb)
    assert a.check(ser)["ok"]
    assert b.check(bat)["ok"]
    jax.clear_caches()


def test_multi_consumer_remote_turn_b_really_batches():
    """A 2-hot remote batch through the workload's remote_turn_b equals
    the two one-hot turns (up to the §9 PA exemption) — the co-scheduled
    drain is semantically the serialized pair, executed in one turn."""
    import repro.workloads.producer_consumer as pc
    mod = workloads.get("producer_consumer_mc")
    bench = mod.build("srsp", 8, seed=1)
    wl = bench.wl
    s = bench.state
    # burn scratch credit so both consumers are drain-ready
    for _ in range(wl.cfg.warmup):
        s = pc._local_turn(wl, s, pc._can_local(wl, s))
    can_r = np.asarray(pc._can_remote(wl, s))
    assert can_r[0] and can_r[1], can_r
    addr = np.asarray(pc._remote_addr(wl, s))
    assert addr[0] != addr[1]                 # partitioned victims
    both = pc._remote_turn_b(wl, s, jnp.asarray([True, True] + [False] * 6))
    mod2 = mod.build("srsp", 8, seed=1)
    s2 = mod2.state
    for _ in range(wl.cfg.warmup):
        s2 = pc._local_turn(wl, s2, pc._can_local(wl, s2))
    s2 = pc._remote_turn(wl, s2, 0)
    s2 = pc._remote_turn(wl, s2, 1)
    _assert_stores_equal(both._replace(store=both.store._replace(pa=None)),
                         s2._replace(store=s2.store._replace(pa=None)),
                         "2-hot remote batch")
    jax.clear_caches()


def test_multi_consumer_defaults_clamp_to_tiny_machines():
    """producer_consumer_mc must build at every n_agents its siblings
    accept — n_agents=2 degrades to one consumer instead of raising."""
    import repro.workloads.producer_consumer_mc as mc
    assert mc.default_consumers(2) == 1
    assert mc.default_consumers(8) == 2
    assert mc.default_consumers(64) == 8
    b = mc.build("srsp", 2, seed=0)
    assert b.wl.cfg.n_consumers == 1


def test_multi_consumer_weakened_protocol_is_caught():
    mod = workloads.get("producer_consumer_mc")
    b = mod.build("srsp", 8, seed=1,
                  proto=faults.no_promotion(P.get_protocol("srsp")))
    final = harness.run_batched(b.wl, b.state, *b.ops)
    res = b.check(final)
    assert not res["ok"] and res["check_fails"] > 0, res
    jax.clear_caches()
