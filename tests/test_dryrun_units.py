"""Unit tests for dry-run machinery that need no forced device count:
HLO collective parsing, spec sanitization, analytic roofline sanity."""
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.dryrun import collective_bytes
from repro.perf.roofline_model import Plan, PLANS, roofline
from repro.configs.base import SHAPES
from repro.models.registry import get_config


HLO = """
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[16,256]{1,0} all-gather(%y), dimensions={0}
  %rs = (f32[4,64]{1,0}, f32[4,64]{1,0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u32[32]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %aa = f32[2,2]{1,0} all-to-all(%w), dimensions={1}
  %ars = f32[8,128]{1,0} all-reduce-start(%x2)
  %dot = f32[8,8]{1,0} dot(%p, %q)
"""


def test_collective_bytes_parses_all_ops():
    out = collective_bytes(HLO)
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["bytes"] == 2 * 8 * 128 * 4
    assert out["all-gather"] == {"count": 1, "bytes": 16 * 256 * 2}
    assert out["reduce-scatter"]["bytes"] == 2 * 4 * 64 * 4  # tuple shapes
    assert out["collective-permute"]["bytes"] == 32 * 4
    assert out["all-to-all"]["count"] == 1
    assert "dot" not in out


def test_sanitize_drops_indivisible_axes():
    import numpy as np
    from jax.sharding import Mesh
    from repro.sharding import sanitize
    import jax
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    # 1-sized axes divide everything; build a fake mesh dict via object
    s = sanitize(P("data", "model"), (10, 16), mesh)
    assert s == P("data", "model")


def test_roofline_terms_positive_and_bound_consistent():
    for arch in ("mistral-large-123b", "deepseek-v3-671b", "xlstm-125m"):
        cfg = get_config(arch)
        r = roofline(cfg, SHAPES["train_4k"], Plan())
        assert r["t_compute_s"] > 0 and r["t_memory_s"] > 0
        assert r["bound"] in ("compute", "memory", "collective")
        assert 0 < r["roofline_frac"] <= 1.0 + 1e-9


def test_perf_plans_improve_mistral_collective_term():
    cfg = get_config("mistral-large-123b")
    base = roofline(cfg, SHAPES["train_4k"], PLANS["baseline"])
    opt = roofline(cfg, SHAPES["train_4k"], PLANS["sp_dots"])
    assert opt["t_collective_s"] < 0.5 * base["t_collective_s"]


def test_serve_replicated_kills_decode_collectives():
    cfg = get_config("qwen2.5-32b")
    base = roofline(cfg, SHAPES["decode_32k"], PLANS["baseline"])
    opt = roofline(cfg, SHAPES["decode_32k"], PLANS["serve_replicated"])
    assert opt["t_collective_s"] < 0.01 * base["t_collective_s"]
    assert opt["bound"] == "memory"
