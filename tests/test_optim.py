"""Optimizer correctness: convergence on a quadratic, factored-state shapes,
clipping, schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import (apply_updates, cosine_schedule,
                                    global_norm, make_optimizer)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_converges_on_quadratic(name):
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    init, update = make_optimizer(name, lr=0.1, warmup=5, total_steps=200,
                                  weight_decay=0.0)
    opt = init(params)

    def loss_fn(p):
        return jnp.mean((p["w"] + p["b"] - target) ** 2)

    loss0 = float(loss_fn(params))
    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        upd, opt, _ = update(g, opt, params)
        params = apply_updates(params, upd)
    assert float(loss_fn(params)) < 0.05 * loss0


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 128)), "emb": jnp.zeros((1000, 64)),
              "scale": jnp.zeros((64,))}
    init, _ = make_optimizer("adafactor")
    st = init(params)
    assert st["f"]["w"]["vr"].shape == (64,)
    assert st["f"]["w"]["vc"].shape == (128,)
    assert st["f"]["scale"]["v"].shape == (64,)
    n_opt = sum(x.size for x in jax.tree.leaves(st))
    n_par = sum(x.size for x in jax.tree.leaves(params))
    assert n_opt < 0.05 * n_par  # sublinear optimizer memory


def test_grad_clip_applies():
    params = {"w": jnp.zeros((4,))}
    init, update = make_optimizer("adamw", lr=1.0)
    opt = init(params)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, gnorm = update(g, opt, params)
    assert float(gnorm) > 1e5  # reported pre-clip norm


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, min_frac=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(55)) < float(lr(20))
