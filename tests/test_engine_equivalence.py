"""Old-vs-new engine equivalence (ISSUE 1 acceptance).

The serial engine (`engine="serial"`) preserves the seed engine's exact
event order — one work-group turn per while-loop trip, smallest clock acts
next — while the batched engine executes provably-commuting pop turns
simultaneously.  These tests pin the contract: identical `proc_errors`,
app solutions, and sync counters (bitwise, not approximately) across all
five paper scenarios, plus the dirty⊆sFIFO flush-completeness invariant
surviving the block-major refactor (hypothesis-free here; the hypothesis
sweep lives in test_protocol.py), plus the Pallas drain-writeback kernel
against its jnp reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol as P
from repro.core.worksteal import WSConfig, run_app
from repro.data.graphs import collab_like, road_like
from repro.kernels.selective_flush.kernel import drain_writeback_pallas
from repro.kernels.selective_flush.ref import drain_writeback_ref

WS = WSConfig(n_wgs=4, chunk_cap=32, n_chunks_max=8)
G = collab_like(n=256, m=3, seed=1)

# counters the acceptance criteria name explicitly; the assertion below
# still compares every counter (they must all match bitwise)
KEY_COUNTERS = ("promotions", "probes", "inv_full", "global_syncs")


def _assert_equivalent(app, g, scenario, max_iters):
    ser = run_app(app, g, scenario, WS, max_iters=max_iters, engine="serial")
    bat = run_app(app, g, scenario, WS, max_iters=max_iters, engine="batched")
    assert ser.proc_errors == 0 and bat.proc_errors == 0, scenario
    np.testing.assert_array_equal(ser.solution, bat.solution)
    for k in KEY_COUNTERS:
        assert ser.counters[k] == bat.counters[k], (scenario, k, ser.counters,
                                                    bat.counters)
    mismatched = {k: (ser.counters[k], bat.counters[k])
                  for k in ser.counters if ser.counters[k] != bat.counters[k]}
    assert not mismatched, (scenario, mismatched)
    jax.clear_caches()


@pytest.mark.parametrize("scenario", [
    "srsp",
    pytest.param("steal_only", marks=pytest.mark.slow),
    pytest.param("rsp", marks=pytest.mark.slow),
    pytest.param("baseline", marks=pytest.mark.slow),
    pytest.param("scope_only", marks=pytest.mark.slow),
])
def test_engines_bitwise_equivalent_pagerank(scenario):
    _assert_equivalent("pagerank", G, scenario, max_iters=2)


@pytest.mark.slow
def test_engines_equivalent_sssp_and_mis():
    _assert_equivalent("sssp", road_like(n=256, seed=3), "srsp", max_iters=4)
    _assert_equivalent("mis", G, "rsp", max_iters=2)


# --------------------------------------------------------------------------
# zero-churn elastic pin (ISSUE 6): the alive-set machinery with an empty
# churn schedule must be bitwise invisible on the paper's main workload
# --------------------------------------------------------------------------

def test_zero_churn_elastic_pin_worksteal():
    from repro import workloads
    from repro.workloads import harness
    for plain, elastic in (("serial", "serial_elastic"),
                           ("batched", "batched_elastic")):
        b = workloads.get("worksteal").build("srsp", 4, seed=3)
        ref = harness.runner(plain)(b.wl, b.state, *b.ops)
        b2 = workloads.get("worksteal").build("srsp", 4, seed=3)
        eb = harness.make_elastic(b2)
        fin = harness.runner(elastic)(eb.wl, eb.state, *eb.ops)
        for la, lb in zip(jax.tree.leaves(ref), jax.tree.leaves(fin.s)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=plain)
        assert bool(np.asarray(fin.alive).all())
    jax.clear_caches()


# --------------------------------------------------------------------------
# observability (ISSUE 7): tracing must never perturb protocol results
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", [
    "producer_consumer",
    pytest.param("reader_lock", marks=pytest.mark.slow),
    pytest.param("kv_directory", marks=pytest.mark.slow),
    pytest.param("worksteal", marks=pytest.mark.slow),
])
def test_trace_on_preserves_results(name):
    """Running a workload with the trace ring enabled must leave every
    non-trace leaf bitwise identical to the trace-off run, and must have
    actually recorded events — the observer-effect contract DESIGN.md
    §11 promises (trace state is carried beside the protocol state and
    written with pure scatters; it never feeds back)."""
    from repro import workloads
    from repro.obs import trace as T
    from repro.workloads import harness
    b = workloads.get(name).build("srsp", 4, seed=3)
    off = harness.run_batched(b.wl, T.strip(b.state), *b.ops)
    b2 = workloads.get(name).build("srsp", 4, seed=3)
    on = harness.run_batched(b2.wl, T.with_trace(b2.state, 512), *b2.ops)
    assert int(on.store.trace.head) > 0, name      # tracing really ran
    for la, lb in zip(jax.tree.leaves(off), jax.tree.leaves(T.strip(on))):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=name)
    assert b2.check(on)["ok"], name
    jax.clear_caches()


# --------------------------------------------------------------------------
# fused megakernel engine (ISSUE 8): engine="fused" must be bitwise the
# batched schedule on every registered workload (DESIGN.md §12)
# --------------------------------------------------------------------------

def _strip_leaves(out):
    from repro.obs import trace as T
    return jax.tree.leaves(out._replace(store=T.strip(out.store)))


@pytest.mark.parametrize("name", ["producer_consumer", "reader_lock",
                                  "kv_directory", "worksteal"])
def test_fused_engine_bitwise_equals_batched(name):
    """The fused trip (one `trip_plan` + at most one masked local turn)
    must reproduce the batched engine's final state bitwise — through
    `trace.strip`, like every cross-engine suite — on all four
    registered workloads under the paper's protocol."""
    from repro import workloads
    from repro.workloads import harness
    b = workloads.get(name).build("srsp", 4, seed=3)
    bat = harness.run_batched(b.wl, b.state, *b.ops)
    b2 = workloads.get(name).build("srsp", 4, seed=3)
    fus = harness.run_fused(b2.wl, b2.state, *b2.ops)
    for la, lb in zip(_strip_leaves(bat), _strip_leaves(fus)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=name)
    assert b2.check(fus)["ok"], name
    jax.clear_caches()


@pytest.mark.slow
def test_fused_engine_equals_batched_other_scenarios():
    """The remote-batching capability differs per protocol (rsp has no
    batched twins; baseline flushes) — the fused restructure must hold
    on those dispatch paths too."""
    from repro import workloads
    from repro.workloads import harness
    for scen in ("rsp", "baseline"):
        b = workloads.get("producer_consumer_mc").build(scen, 4, seed=3)
        bat = harness.run_batched(b.wl, b.state, *b.ops)
        b2 = workloads.get("producer_consumer_mc").build(scen, 4, seed=3)
        fus = harness.run_fused(b2.wl, b2.state, *b2.ops)
        for la, lb in zip(_strip_leaves(bat), _strip_leaves(fus)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                          err_msg=scen)
        jax.clear_caches()


@pytest.mark.slow
def test_fused_many_equals_batched_many():
    """The sweep's replica-packed path: `run_fused_many` vs
    `run_batched_many` (conds lower to selects under vmap — the fused
    single-local-turn restructure must stay bitwise there too)."""
    from repro import workloads
    from repro.workloads import harness
    mod = workloads.get("kv_directory")
    b = mod.build("srsp", 4, seed=0)
    seeds = jnp.arange(2, dtype=jnp.int32)
    states = jax.vmap(lambda s: mod.init_state(b.wl, s))(seeds)
    bat = harness.runner_many("batched")(b.wl, states)
    states2 = jax.vmap(lambda s: mod.init_state(b.wl, s))(seeds)
    fus = harness.runner_many("fused")(b.wl, states2)
    for la, lb in zip(_strip_leaves(bat), _strip_leaves(fus)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    jax.clear_caches()


def test_fused_engine_trace_on_preserves_results():
    """Observer-effect contract on the fused engine: the trace ring live
    must leave every non-trace leaf bitwise identical (the plan kernel
    sits outside the charge/record path — DESIGN.md §12)."""
    from repro import workloads
    from repro.obs import trace as T
    from repro.workloads import harness
    b = workloads.get("producer_consumer").build("srsp", 4, seed=3)
    off = harness.run_fused(b.wl, T.strip(b.state), *b.ops)
    b2 = workloads.get("producer_consumer").build("srsp", 4, seed=3)
    on = harness.run_fused(b2.wl, T.with_trace(b2.state, 512), *b2.ops)
    assert int(on.store.trace.head) > 0
    for la, lb in zip(jax.tree.leaves(off), jax.tree.leaves(T.strip(on))):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    jax.clear_caches()


# --------------------------------------------------------------------------
# dirty ⊆ sFIFO invariant through the block-major batched ops
# --------------------------------------------------------------------------

CFG = P.ProtoConfig(n_caches=4, n_words=256)


def _dirty_blocks(st, c):
    return set(np.nonzero(np.asarray(P.wdirty_bool(st)[c]).any(axis=-1))[0])


def _fifo_blocks(st, c):
    return set(int(a) for a in np.asarray(st.fifo.addrs[c]) if a >= 0)


def test_dirty_subset_of_fifo_survives_block_major_ops():
    """Random op soup over BOTH API layers (scalar and masked-batch ops);
    after every op each cache's dirty blocks are a subset of its sFIFO, so
    a drain is always a complete flush."""
    rng = np.random.default_rng(7)
    st = P.make_store(CFG)
    n = CFG.n_caches
    for step in range(30):
        op = rng.integers(0, 7)
        cid = int(rng.integers(0, n))
        addr = jnp.int32(int(rng.integers(0, 15)) * 16 + int(rng.integers(0, 16)))
        if op == 0:
            st, _ = P.store_word(CFG, st, cid, addr, step)
        elif op == 1:
            st, _ = P.load(CFG, st, cid, addr)
        elif op == 2:
            st = P.local_release(CFG, st, cid, addr, 1)
        elif op == 3:
            st, _ = P.local_acquire(CFG, st, cid, addr, 0, 1)
        elif op == 4:
            st, _ = P.srsp_remote_acquire(CFG, st, cid, addr, 0, 1)
        elif op == 5:
            # masked multi-cache store: disjoint per-cache addresses
            mask = jnp.asarray(rng.integers(0, 2, n).astype(bool))
            addrs = jnp.asarray((rng.permutation(n) * 64 + 3).astype(np.int32))
            st, _ = P.b_store_word(CFG, st, mask, addrs,
                                   jnp.full((n,), step, jnp.int32))
        else:
            mask = jnp.asarray(rng.integers(0, 2, n).astype(bool))
            addrs = jnp.asarray((rng.permutation(n) * 64 + 5).astype(np.int32))
            st, _ = P.local_acquire_b(CFG, st, mask, addrs, 0, 1)
        for c in range(n):
            assert _dirty_blocks(st, c) <= _fifo_blocks(st, c), (step, op, c)
    for c in range(n):
        st, _ = P.drain_fifo_all(CFG, st, c)
    assert not bool(np.asarray(P.wdirty_bool(st)).any())


def test_batched_ops_match_scalar_ops_single_lane():
    """A batched op with a one-hot mask must equal the scalar-cid op."""
    ops_scalar = P.make_store(CFG)
    ops_batch = P.make_store(CFG)
    rng = np.random.default_rng(3)
    for step in range(20):
        cid = int(rng.integers(0, CFG.n_caches))
        addr = int(rng.integers(0, CFG.n_words))
        hot = jnp.arange(CFG.n_caches) == cid
        addrs = jnp.full((CFG.n_caches,), addr, jnp.int32)
        vals = jnp.full((CFG.n_caches,), step, jnp.int32)
        which = rng.integers(0, 3)
        if which == 0:
            ops_scalar, _ = P.store_word(CFG, ops_scalar, cid, addr, step)
            ops_batch, _ = P.b_store_word(CFG, ops_batch, hot, addrs, vals)
        elif which == 1:
            ops_scalar, a = P.load(CFG, ops_scalar, cid, addr)
            ops_batch, b = P.b_load(CFG, ops_batch, hot, addrs)
            assert int(a) == int(b[cid])
        else:
            ops_scalar = P.local_release(CFG, ops_scalar, cid, addr, step)
            ops_batch = P.local_release_b(CFG, ops_batch, hot, addrs, vals)
    for a, b in zip(jax.tree.leaves(ops_scalar), jax.tree.leaves(ops_batch)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Pallas drain-writeback kernel vs jnp reference
# --------------------------------------------------------------------------

def test_drain_writeback_pallas_matches_ref():
    rng = np.random.default_rng(0)
    nb, W, m = 32, 16, 12
    l2 = jnp.asarray(rng.integers(0, 100, (nb, W)), jnp.int32)
    rows = jnp.asarray(rng.integers(100, 200, (m, W)), jnp.int32)
    dirty = jnp.asarray(rng.integers(0, 2, (m, W)).astype(bool))
    # unique destinations plus -1 padding
    idx = np.full(m, -1, np.int32)
    idx[:8] = rng.choice(nb, size=8, replace=False)
    got = drain_writeback_pallas(l2, rows, dirty, jnp.asarray(idx),
                                 interpret=True)
    want = drain_writeback_ref(l2, rows, dirty, jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_drain_writeback_packed_mask_matches_boolean():
    """The packed uint32 word-bitmask dirty rows (DESIGN.md §8) must drive
    the scatter identically to the boolean rows they encode — kernel and
    jnp reference, including word-boundary lanes (W not divisible by 32)."""
    from repro.core import bitmask
    rng = np.random.default_rng(5)
    for W in (16, 40):          # 1 lane ragged / 2 lanes ragged
        nb, m = 16, 10
        l2 = jnp.asarray(rng.integers(0, 100, (nb, W)), jnp.int32)
        rows = jnp.asarray(rng.integers(100, 200, (m, W)), jnp.int32)
        dirty = jnp.asarray(rng.integers(0, 2, (m, W)).astype(bool))
        idx = np.full(m, -1, np.int32)
        idx[:7] = rng.choice(nb, size=7, replace=False)
        idx = jnp.asarray(idx)
        packed = bitmask.pack(dirty)
        want = drain_writeback_ref(l2, rows, dirty, idx)
        np.testing.assert_array_equal(
            np.asarray(drain_writeback_ref(l2, rows, packed, idx)),
            np.asarray(want), err_msg=f"packed ref W={W}")
        np.testing.assert_array_equal(
            np.asarray(drain_writeback_pallas(l2, rows, packed, idx,
                                              interpret=True)),
            np.asarray(want), err_msg=f"packed pallas W={W}")


def test_drain_writeback_duplicate_disjoint_dirty():
    """Two caches flushing different words of the same block (block-level
    false sharing) must both land; order only matters for overlapping dirty
    words, which a well-synchronized program never produces."""
    nb, W = 4, 16
    l2 = jnp.zeros((nb, W), jnp.int32)
    rows = jnp.stack([jnp.full((W,), 7, jnp.int32),
                      jnp.full((W,), 9, jnp.int32)])
    dirty = jnp.stack([jnp.arange(W) < 8, jnp.arange(W) >= 8])
    idx = jnp.asarray([2, 2], jnp.int32)
    want = drain_writeback_ref(l2, rows, dirty, idx)
    got = drain_writeback_pallas(l2, rows, dirty, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(want[2]),
                                  np.asarray(jnp.where(jnp.arange(W) < 8, 7, 9)))
