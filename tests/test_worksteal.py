"""Work-stealing harness tests: the five paper scenarios end-to-end on small
graphs — protocol integrity (every chunk processed exactly once THROUGH the
simulated memory), solution correctness, and the paper's qualitative
ordering (sRSP >= RSP, both beat global-sync baselines).

Scenario sims are compiled once per module (fixture) and caches cleared
afterwards — the compiled round loops are large."""
import jax
import numpy as np
import pytest

from repro.core.worksteal import WSConfig, run_app, reference_solution
from repro.data.graphs import collab_like, road_like

WS = WSConfig(n_wgs=4, chunk_cap=32, n_chunks_max=16)
G = collab_like(n=384, m=3, seed=1)
SCENARIOS = ["baseline", "scope_only", "steal_only", "rsp", "srsp"]


@pytest.fixture(scope="module")
def results():
    out = {s: run_app("pagerank", G, s, WS, max_iters=2) for s in SCENARIOS}
    yield out
    jax.clear_caches()


def test_every_chunk_processed_exactly_once(results):
    for s, r in results.items():
        assert r.proc_errors == 0, (s, r.proc_errors)


def test_pagerank_solution_matches_reference(results):
    ref = reference_solution("pagerank", G, max_iters=2)
    for s in ("baseline", "srsp", "rsp"):
        np.testing.assert_allclose(results[s].solution, ref, rtol=1e-5)


def test_paper_ordering_holds(results):
    base = results["baseline"].makespan
    assert results["steal_only"].makespan < base          # balance helps
    assert results["srsp"].makespan <= results["rsp"].makespan  # the claim
    assert results["srsp"].counters["inv_full"] < \
        results["rsp"].counters["inv_full"]
    assert results["srsp"].counters["l2_accesses"] <= \
        results["rsp"].counters["l2_accesses"]            # Fig. 5


def test_stealing_actually_happens(results):
    assert results["srsp"].counters["steals"] > 0


def test_srsp_beats_global_sync_scenarios(results):
    assert results["srsp"].makespan < results["baseline"].makespan
    assert results["srsp"].makespan < results["steal_only"].makespan


def test_sssp_and_mis_on_srsp():
    g = road_like(n=400, seed=3)
    ws = WSConfig(n_wgs=4, chunk_cap=32, n_chunks_max=16)
    ref = reference_solution("sssp", g, max_iters=6)
    r = run_app("sssp", g, "srsp", ws, max_iters=6)
    assert r.proc_errors == 0
    np.testing.assert_array_equal(r.solution, ref)
    ref_m = reference_solution("mis", G, max_iters=4)
    rm = run_app("mis", G, "srsp", WS, max_iters=4)
    assert rm.proc_errors == 0
    np.testing.assert_array_equal(rm.solution, ref_m)
    jax.clear_caches()
