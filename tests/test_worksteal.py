"""Work-stealing harness tests: the paper scenarios end-to-end on small
graphs — protocol integrity (every chunk processed exactly once THROUGH the
simulated memory), solution correctness, and the paper's qualitative
ordering (sRSP >= RSP, both beat global-sync baselines).

Tier-1 runs the sRSP scenario (the paper's contribution and the default
engine's hottest path); the full five-scenario sweep, the cross-scenario
ordering claims and the sssp/mis apps are `slow` (run with `make test-slow`).
Scenario sims are compiled once per fixture and caches cleared afterwards —
the compiled round loops are large."""
import jax
import numpy as np
import pytest

from repro.core.worksteal import WSConfig, run_app, reference_solution
from repro.data.graphs import collab_like, road_like

WS = WSConfig(n_wgs=4, chunk_cap=32, n_chunks_max=16)
G = collab_like(n=384, m=3, seed=1)
SCENARIOS = ["baseline", "scope_only", "steal_only", "rsp", "srsp"]


@pytest.fixture(scope="module")
def srsp_result():
    out = run_app("pagerank", G, "srsp", WS, max_iters=2)
    yield out
    jax.clear_caches()


@pytest.fixture(scope="module")
def results():
    out = {s: run_app("pagerank", G, s, WS, max_iters=2) for s in SCENARIOS}
    yield out
    jax.clear_caches()


def test_srsp_every_chunk_processed_exactly_once(srsp_result):
    assert srsp_result.proc_errors == 0


def test_srsp_pagerank_solution_matches_reference(srsp_result):
    ref = reference_solution("pagerank", G, max_iters=2)
    np.testing.assert_allclose(srsp_result.solution, ref, rtol=1e-5)


def test_srsp_stealing_actually_happens(srsp_result):
    assert srsp_result.counters["steals"] > 0
    assert srsp_result.counters["promotions"] > 0  # PA-TBL promotion fired


@pytest.mark.slow
def test_every_chunk_processed_exactly_once(results):
    for s, r in results.items():
        assert r.proc_errors == 0, (s, r.proc_errors)


@pytest.mark.slow
def test_pagerank_solution_matches_reference(results):
    ref = reference_solution("pagerank", G, max_iters=2)
    for s in ("baseline", "srsp", "rsp"):
        np.testing.assert_allclose(results[s].solution, ref, rtol=1e-5)


@pytest.mark.slow
def test_paper_ordering_holds(results):
    base = results["baseline"].makespan
    assert results["steal_only"].makespan < base          # balance helps
    assert results["srsp"].makespan <= results["rsp"].makespan  # the claim
    assert results["srsp"].counters["inv_full"] < \
        results["rsp"].counters["inv_full"]
    assert results["srsp"].counters["l2_accesses"] <= \
        results["rsp"].counters["l2_accesses"]            # Fig. 5
    assert results["srsp"].makespan < results["baseline"].makespan
    assert results["srsp"].makespan < results["steal_only"].makespan


@pytest.mark.slow
def test_sssp_and_mis_on_srsp():
    g = road_like(n=400, seed=3)
    ws = WSConfig(n_wgs=4, chunk_cap=32, n_chunks_max=16)
    ref = reference_solution("sssp", g, max_iters=6)
    r = run_app("sssp", g, "srsp", ws, max_iters=6)
    assert r.proc_errors == 0
    np.testing.assert_array_equal(r.solution, ref)
    ref_m = reference_solution("mis", G, max_iters=4)
    rm = run_app("mis", G, "srsp", WS, max_iters=4)
    assert rm.proc_errors == 0
    np.testing.assert_array_equal(rm.solution, ref_m)
    jax.clear_caches()
