"""Per-arch smoke tests (reduced same-family configs, CPU): one forward and
one train step, output shapes, no NaNs — plus decode-vs-full-forward
consistency (validates every KV-cache / recurrent-state path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, applicable
from repro.models.registry import ARCH_IDS, build, get_config, input_specs
from repro.optim import make_optimizer
from repro.train.train_step import make_train_step

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)

# tier-1 smokes a dense, an MoE-heavy and a multimodal representative;
# the remaining (slower-compiling) architectures run under `-m slow`
FAST_ARCHS = {"qwen2.5-32b", "granite-moe-1b-a400m", "llava-next-mistral-7b"}
ARCH_PARAMS = [a if a in FAST_ARCHS else
               pytest.param(a, marks=pytest.mark.slow) for a in ARCH_IDS]


def _batch(cfg, b, s, labels=True):
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    if labels:
        batch["labels"] = jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)),
                                      jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            RNG.normal(size=(b, cfg.n_patches, 1024)), jnp.float32)
    if cfg.family == "encdec":
        batch["src_embeds"] = jnp.asarray(
            RNG.normal(size=(b, s, 1024)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, 2, 32)
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert jnp.isfinite(loss), arch
    opt_init, opt_update = make_optimizer("adamw", lr=1e-3)
    step = make_train_step(model, opt_init, opt_update, n_micro=2)
    opt = opt_init(params)
    params2, opt2, m = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(m["loss"]) and jnp.isfinite(m["gnorm"])
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe is not None:  # disable capacity drops for exactness
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    model = build(cfg)
    params = model.init(KEY)
    b, s = 2, 16
    batch = _batch(cfg, b, s, labels=False)
    full_logits, _ = model.prefill(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s - 4]
    logits, cache = model.prefill(params, pre)
    cache = model.grow_cache(cache, s)
    for i in range(s - 4, s):
        logits, cache = model.decode_step(
            params, cache, batch["tokens"][:, i:i + 1],
            jnp.full((b,), i, jnp.int32))
    rel = float(jnp.abs(full_logits - logits).max()) / \
        float(jnp.abs(full_logits).max())
    assert rel < 2e-3, (arch, rel)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_applicable_shapes(arch):
    cfg = get_config(arch)
    for name, shape in SHAPES.items():
        if not applicable(cfg, shape):
            assert name == "long_500k"
            continue
        specs = input_specs(cfg, shape)
        leaves = jax.tree.leaves(specs)
        assert leaves and all(isinstance(x, jax.ShapeDtypeStruct)
                              for x in leaves)


def test_long500k_runs_only_for_ssm_families():
    runs = [a for a in ARCH_IDS
            if applicable(get_config(a), SHAPES["long_500k"])]
    assert sorted(runs) == ["xlstm-125m", "zamba2-1.2b"]


def test_exact_configs_match_assignment():
    """Published scales pinned exactly (arch brief)."""
    c = get_config("deepseek-v3-671b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (61, 7168, 128, 129280)
    assert c.moe.n_experts == 256 and c.moe.top_k == 8 and c.moe.n_shared == 1
    assert c.mla.kv_lora_rank == 512
    c = get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (88, 12288, 96, 8, 28672, 32768)
    c = get_config("qwen1.5-32b")
    assert c.n_kv_heads == 40 and c.qkv_bias
    c = get_config("zamba2-1.2b")
    assert c.ssm.d_state == 64 and c.n_layers == 38
    c = get_config("seamless-m4t-large-v2")
    assert c.vocab == 256206 and c.enc_layers == 24
    c = get_config("granite-moe-1b-a400m")
    assert c.moe.n_experts == 32 and c.moe.top_k == 8 and c.vocab == 49155
    c = get_config("stablelm-12b")
    assert (c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == (5120, 32, 8, 13824)
    c = get_config("qwen2.5-32b")
    assert (c.d_ff, c.vocab) == (27648, 152064)
    c = get_config("llava-next-mistral-7b")
    assert (c.n_layers, c.d_ff) == (32, 14336)
    c = get_config("xlstm-125m")
    assert (c.n_layers, c.d_model, c.n_heads) == (12, 768, 4)


def test_param_counts_near_published():
    """Analytic parameter counts land near the published totals."""
    expect = {"mistral-large-123b": 123e9, "deepseek-v3-671b": 671e9,
              "qwen2.5-32b": 32.5e9, "stablelm-12b": 12e9,
              "llava-next-mistral-7b": 7.2e9, "xlstm-125m": 125e6,
              "granite-moe-1b-a400m": 1.3e9, "zamba2-1.2b": 1.2e9}
    for arch, target in expect.items():
        got = get_config(arch).param_count()
        assert 0.7 * target < got < 1.45 * target, (arch, got, target)
    a400 = get_config("granite-moe-1b-a400m").active_param_count()
    assert 0.25e9 < a400 < 0.6e9, a400
    ds_act = get_config("deepseek-v3-671b").active_param_count()
    assert 25e9 < ds_act < 50e9, ds_act  # ~37B active
