"""Slot-based continuous batching (ISSUE 9 satellite).

The engine packs `slots` sequences into one jitted vmapped decode step
and refills a finished slot from the queue without draining the batch.
Greedy decode per slot is independent of its neighbors, so the engine's
outputs must EQUAL running each request alone through the serial
prefill+decode loop (the old engine's exact code path, inlined here as
the reference).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import build, get_config
from repro.serve.engine import Engine, Request

MAX_LEN = 48


def _serial_reference(model, params, r):
    logits, cache = model.prefill(params,
                                  {"tokens": jnp.asarray(r.prompt[None])})
    cache = model.grow_cache(cache, MAX_LEN)
    toks = [int(jnp.argmax(logits[0]))]
    kv = len(r.prompt)
    for _ in range(r.max_new_tokens - 1):
        t = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = model.decode_step(params, cache, t,
                                          jnp.asarray([kv], jnp.int32))
        kv += 1
        toks.append(int(jnp.argmax(logits[0])))
    return np.asarray(toks, np.int32)


def test_continuous_batching_matches_serial_reference():
    cfg = get_config("qwen2.5-32b", smoke=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    # more requests than slots, ragged prompts and budgets, one request
    # that finishes at prefill (max_new_tokens=1) so a slot frees early
    lens, budgets = (5, 3, 7, 5, 3), (4, 6, 1, 5, 3)
    prompts = [rng.integers(0, cfg.vocab, (n,)).astype(np.int32)
               for n in lens]
    ref = [_serial_reference(model, params,
                             Request(prompt=p, max_new_tokens=m))
           for p, m in zip(prompts, budgets)]
    eng = Engine(model, params, max_len=MAX_LEN, slots=2)
    out = eng.generate([Request(prompt=p, max_new_tokens=m)
                        for p, m in zip(prompts, budgets)])
    for i, (a, b) in enumerate(zip(ref, out)):
        assert len(b.out) == budgets[i]
        np.testing.assert_array_equal(a, b.out, err_msg=f"request {i}")
    jax.clear_caches()
