"""Pallas TPU kernel: flash-decode — single-token attention over a long,
possibly partially-filled KV cache.

Decode attention is purely HBM-bandwidth-bound (every step streams the whole
KV cache once, q is one token).  The kernel tiles the cache into
(block_k, d) VMEM chunks on the innermost grid axis and carries the
online-softmax state in VMEM scratch; per-(batch, head) the chunk loop is
sequential so the running (m, l, acc) recurrence is exact.

The `kv_len` scalar is prefetched so chunks entirely past the valid prefix
are skipped (pl.when) — with a ring-buffer cache this is what keeps
long_500k decode from paying for unwritten cache tail.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, block_k: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_k = pl.num_programs(2)
    kv_len = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    @pl.when(j * block_k < kv_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [1, d] row
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)               # [bk, d]
        s = (k @ q.T).T                                   # [1, bk]
        pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                            # [1, bk]
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v       # [1, d]
        m_scr[...] = m_new

    @pl.when(j == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def flash_decode_pallas(q, k, v, kv_len, *, scale: float | None = None,
                        block_k: int = 512, interpret: bool = False):
    """q [B, Hq, D]; k, v [B, Hkv, S, D]; kv_len [B] -> [B, Hq, D]."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    block_k = min(block_k, s)
    assert s % block_k == 0

    q4 = q[:, :, None, :]  # [B, Hq, 1, D]
    grid = (b, hq, s // block_k)
    kern = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bb, h, j, L: (bb, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, j, L, g=group: (bb, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, j, L, g=group: (bb, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bb, h, j, L: (bb, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32), q4, k, v)
    return out[:, :, 0, :]
