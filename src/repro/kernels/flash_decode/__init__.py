from repro.kernels.flash_decode.ops import flash_decode  # noqa: F401
