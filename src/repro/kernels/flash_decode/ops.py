"""Jit'd public wrapper for flash-decode with backend dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_decode.kernel import flash_decode_pallas
from repro.kernels.flash_decode import ref


def flash_decode(q, k, v, kv_len, *, scale: float | None = None,
                 block_k: int = 512, use_pallas: bool = True,
                 interpret: bool | None = None) -> jnp.ndarray:
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, kv_len, scale=scale)
    if interpret is None:
        interpret = default_interpret()
    return flash_decode_pallas(q, k, v, kv_len, scale=scale,
                               block_k=block_k, interpret=interpret)
