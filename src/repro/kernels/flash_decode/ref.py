"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, kv_len, *, scale: float | None = None):
    """q [B, Hq, D]; k, v [B, Hkv, S, D]; kv_len [B] int32 (valid prefix).
    Returns [B, Hq, D]."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    logits = jnp.einsum("bhd,bhsd->bhs", qf, kf)
    pos = jnp.arange(s)[None, None, :]
    logits = jnp.where(pos < kv_len[:, None, None], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", p, vf).astype(q.dtype)
