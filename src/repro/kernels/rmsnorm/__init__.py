from repro.kernels.rmsnorm.ops import rmsnorm  # noqa: F401
