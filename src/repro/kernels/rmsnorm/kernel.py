"""Pallas TPU kernel: fused RMSNorm over the last axis.

VMEM tiling: a (block_rows, d) tile of activations plus the (d,) scale vector
live in VMEM; the reduction and rescale fuse into one pass (one HBM read,
one HBM write — the op is purely memory-bound, so fusion is the whole win).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
                   block_rows: int = 8, interpret: bool = False) -> jnp.ndarray:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    padded = (rows + block_rows - 1) // block_rows * block_rows
    if padded != rows:
        x2 = jnp.pad(x2, ((0, padded - rows), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(padded // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
