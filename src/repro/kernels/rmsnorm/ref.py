"""Pure-jnp oracle for fused RMSNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)
