"""Jit'd public wrapper for RMSNorm with backend dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
from repro.kernels.rmsnorm import ref


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
            *, use_pallas: bool = False, interpret: bool | None = None
            ) -> jnp.ndarray:
    """RMSNorm. use_pallas=True selects the fused TPU kernel (interpret mode
    on CPU); the default jnp path is used inside differentiable model code."""
    if not use_pallas:
        return ref.rmsnorm_ref(x, w, eps)
    if interpret is None:
        interpret = default_interpret()
    return rmsnorm_pallas(x, w, eps=eps, interpret=interpret)
