"""Pallas TPU kernel: fused MoE router (softmax + iterative top-k + renorm).

One (block_t, E) tile of router logits is loaded to VMEM once; softmax and k
argmax/mask iterations (k is small and static) run entirely in registers/VMEM,
emitting the compact (weights, indices) pair.  Fusing avoids k round trips
to HBM that a lowered lax.top_k chain would cost on the [T, E] probabilities.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _router_kernel(x_ref, w_ref, i_ref, *, k: int, renormalize: bool):
    x = x_ref[...].astype(jnp.float32)                  # [bt, E]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)

    cols = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    remaining = probs
    for kk in range(k):
        v = jnp.max(remaining, axis=-1)                 # [bt]
        idx = jnp.argmax(remaining, axis=-1).astype(jnp.int32)
        w_ref[:, kk] = v
        i_ref[:, kk] = idx
        remaining = jnp.where(cols == idx[:, None], NEG_INF, remaining)
    if renormalize:
        total = jnp.zeros_like(w_ref[:, 0])
        for kk in range(k):
            total = total + w_ref[:, kk]
        for kk in range(k):
            w_ref[:, kk] = w_ref[:, kk] / jnp.maximum(total, 1e-30)


@functools.partial(jax.jit, static_argnames=("k", "renormalize", "block_t",
                                             "interpret"))
def topk_router_pallas(logits: jnp.ndarray, k: int, *, renormalize: bool = True,
                       block_t: int = 256, interpret: bool = False):
    t, e = logits.shape
    block_t = min(block_t, t)
    padded = (t + block_t - 1) // block_t * block_t
    x = logits
    if padded != t:
        x = jnp.pad(x, ((0, padded - t), (0, 0)))
    kern = functools.partial(_router_kernel, k=k, renormalize=renormalize)
    w, i = pl.pallas_call(
        kern,
        grid=(padded // block_t,),
        in_specs=[pl.BlockSpec((block_t, e), lambda b: (b, 0))],
        out_specs=[pl.BlockSpec((block_t, k), lambda b: (b, 0)),
                   pl.BlockSpec((block_t, k), lambda b: (b, 0))],
        out_shape=[jax.ShapeDtypeStruct((padded, k), jnp.float32),
                   jax.ShapeDtypeStruct((padded, k), jnp.int32)],
        interpret=interpret,
    )(x)
    return w[:t], i[:t]
