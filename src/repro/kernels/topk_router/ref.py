"""Pure-jnp oracle: MoE router = softmax + top-k + renormalize."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_router_ref(logits: jnp.ndarray, k: int, *, renormalize: bool = True):
    """logits [T, E] -> (weights [T, k] f32, idx [T, k] i32), descending."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, k)
    if renormalize:
        vals = vals / jnp.sum(vals, axis=-1, keepdims=True)
    return vals, idx.astype(jnp.int32)
