from repro.kernels.topk_router.ops import topk_router  # noqa: F401
