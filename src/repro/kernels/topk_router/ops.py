"""Jit'd public wrapper for the fused MoE router."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.topk_router.kernel import topk_router_pallas
from repro.kernels.topk_router import ref


def topk_router(logits: jnp.ndarray, k: int, *, renormalize: bool = True,
                use_pallas: bool = False, interpret: bool | None = None):
    """Router for MoE dispatch.  The jnp path is differentiable and used in
    training; the Pallas path is the fused serving kernel."""
    if not use_pallas:
        return ref.topk_router_ref(logits, k, renormalize=renormalize)
    if interpret is None:
        interpret = default_interpret()
    return topk_router_pallas(logits, k, renormalize=renormalize,
                              interpret=interpret)
