"""Shared kernel utilities: process-wide execution-mode selection.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling).  On this CPU
container they are validated with interpret=True, which executes the
kernel body in Python — far too slow to ever be a silent benchmark path.
`kernel_mode()` therefore picks the mode ONCE per process (the old
`default_interpret()` re-read the backend on every call, so a mid-process
backend change could split one run across modes):

  * "pallas"     compiled Pallas kernels (backend is TPU)
  * "ref"        the jnp references in each kernel package's ref.py —
                 the CPU fast path AND the oracle the equivalence tests
                 pin the kernels against
  * "interpret"  interpret=True Pallas everywhere — debugging only,
                 opt-in via REPRO_KERNEL_MODE=interpret

REPRO_KERNEL_MODE (read at import, like REPRO_NO_PACK/REPRO_NO_DONATE)
overrides the automatic choice with any of the three names.  Benchmarks
call `note_benchmark()` before timing and record `kernel_mode()` in
their JSON, so an interpret-mode number can never masquerade as a real
measurement (warned loudly, and visible in the artifact).
"""
from __future__ import annotations

import functools
import os
import warnings

import jax

_MODES = ("pallas", "ref", "interpret")
_FORCE = os.environ.get("REPRO_KERNEL_MODE", "")


@functools.lru_cache(maxsize=None)
def kernel_mode() -> str:
    """Process-wide kernel execution mode ("pallas" / "ref" / "interpret"),
    chosen once on first use and cached for the life of the process."""
    if _FORCE:
        if _FORCE not in _MODES:
            raise ValueError(f"REPRO_KERNEL_MODE={_FORCE!r}; "
                             f"valid: {_MODES}")
        return _FORCE
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def use_pallas() -> bool:
    """Default kernel-vs-ref dispatch: Pallas kernels unless mode is
    "ref" (interpret mode still routes through pallas_call)."""
    return kernel_mode() != "ref"


def interpret() -> bool:
    """Default interpret flag for pallas_call when `use_pallas()`."""
    return kernel_mode() == "interpret"


def default_interpret() -> bool:
    """Interpret flag for callers that force the Pallas path (kernel
    equivalence tests): interpret everywhere except a real TPU.  Kept
    for back-compat; now derived from the cached process-wide mode."""
    return kernel_mode() != "pallas"


def note_benchmark(what: str) -> str:
    """Benchmark entry hook: returns `kernel_mode()` for the bench JSON
    and warns loudly if the process would time interpret-mode kernels —
    a number from the Python interpreter loop is not a measurement."""
    mode = kernel_mode()
    if mode == "interpret":
        warnings.warn(
            f"{what}: benchmarking with kernel_mode='interpret' "
            f"(REPRO_KERNEL_MODE) — interpret-mode Pallas timings are "
            f"not meaningful; unset REPRO_KERNEL_MODE or use the jnp "
            f"reference path", stacklevel=2)
    return mode


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
