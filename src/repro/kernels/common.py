"""Shared kernel utilities.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling).  On this CPU
container they are validated with interpret=True, which executes the kernel
body in Python; `default_interpret()` picks the right mode automatically.
"""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
