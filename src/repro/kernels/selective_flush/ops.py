"""Jit'd public wrappers for selective flush / apply.

`selective_flush` dispatches to the Pallas gather kernel (TPU, or
interpret=True during CPU validation); `selective_apply` is the scatter
inverse, left to XLA's native scatter (no Pallas win on TPU — see
DESIGN.md kernel notes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.common import default_interpret
from repro.kernels.selective_flush.kernel import (drain_writeback_pallas,
                                                  selective_flush_pallas)
from repro.kernels.selective_flush import ref


def selective_flush(bank: jnp.ndarray, indices: jnp.ndarray,
                    *, use_pallas: bool | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Compact bank rows named by `indices` (-1 padded) into a dense buffer."""
    if use_pallas is None:
        use_pallas = True
    if not use_pallas:
        return ref.selective_flush_ref(bank, indices)
    if interpret is None:
        interpret = default_interpret()
    return selective_flush_pallas(bank, indices, interpret=interpret)


@jax.jit
def selective_apply(bank: jnp.ndarray, updates: jnp.ndarray,
                    indices: jnp.ndarray) -> jnp.ndarray:
    """Scatter compacted updates back into the bank (the remote 'acquire'
    side applying a flushed delta)."""
    return ref.selective_apply_ref(bank, updates, indices)


def drain_writeback(l2: jnp.ndarray, rows: jnp.ndarray, dirty: jnp.ndarray,
                    indices: jnp.ndarray, *, use_pallas: bool | None = None,
                    interpret: bool | None = None) -> jnp.ndarray:
    """Merge drained L1 blocks into the L2 bank under a per-word dirty mask
    (the protocol engine's drain/writeback scatter — see protocol.b_drain).

    `dirty` is either boolean [m, W] or packed uint32 word-bitmask rows
    [m, ceil(W/32)] (DESIGN.md §8) — the packed form is what the packed
    metadata engine passes straight from its wdirty plane; both kernel and
    reference expand it themselves, so no caller ever unpacks.

    Dispatches to the Pallas scatter kernel on TPU; on CPU the jnp
    reference is both the validation oracle and the fast path (XLA fuses
    the scatter-max/gather pair), so interpret-mode Pallas is reserved for
    the kernel equivalence tests.  The mode is chosen once per process
    (`kernels.common.kernel_mode()`), never re-derived mid-run."""
    if use_pallas is None:
        use_pallas = common.use_pallas()
    # profiler annotation: the drain scatter is the megakernel-fusion
    # candidate (ROADMAP) — make it findable in jax.profiler traces
    with jax.named_scope("kernels.drain_writeback"):
        if not use_pallas:
            return ref.drain_writeback_ref(l2, rows, dirty, indices)
        if interpret is None:
            interpret = default_interpret()
        return drain_writeback_pallas(l2, rows, dirty, indices,
                                      interpret=interpret)
