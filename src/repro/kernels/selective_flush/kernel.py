"""Pallas TPU kernel: selective flush = gather-compact of dirty blocks.

This is the TPU-native realization of the paper's selective-flush (§4.2):
instead of a GPU L1 walking its sFIFO and writing blocks back one by one,
the TPU owner gathers exactly the dirty parameter/state blocks named by the
sFIFO into a contiguous staging buffer — which then feeds one small
collective (the "writeback to global scope").

TPU-idiomatic pattern: the dirty-block index list is *scalar-prefetched*
(PrefetchScalarGridSpec) so the BlockSpec index_map can select a dynamic HBM
block per grid step — dynamic gather without scatter/gather instructions,
driven entirely by the DMA engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flush_kernel(idx_ref, bank_ref, out_ref):
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0

    @pl.when(valid)
    def _copy():
        out_ref[...] = bank_ref[...]

    @pl.when(jnp.logical_not(valid))
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_flush_pallas(bank: jnp.ndarray, indices: jnp.ndarray,
                           *, interpret: bool = False) -> jnp.ndarray:
    """bank [n_blocks, block_size], indices [max_dirty] int32 (-1 pad)
    -> [max_dirty, block_size]."""
    n_blocks, block_size = bank.shape
    max_dirty = indices.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(max_dirty,),
        in_specs=[
            # clamp pad entries (-1) in the index_map; the kernel zeroes them
            pl.BlockSpec((1, block_size),
                         lambda i, idx: (jnp.maximum(idx[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_size), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _flush_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((max_dirty, block_size), bank.dtype),
        interpret=interpret,
    )(indices, bank)


def _writeback_kernel(idx_ref, l2_ref, row_ref, dirty_ref, out_ref):
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0
    sel = (dirty_ref[...] != 0) & valid
    # The index list is pre-sorted, so duplicate destinations arrive in
    # consecutive grid steps and the output block stays resident: merge onto
    # the previous step's result instead of re-reading the (stale) L2 block.
    first = (i == 0) | (idx_ref[i] != idx_ref[jnp.maximum(i - 1, 0)])
    base = jnp.where(first, l2_ref[...], out_ref[...])
    out_ref[...] = jnp.where(sel, row_ref[...], base)


def _writeback_kernel_packed(idx_ref, l2_ref, row_ref, dirty_ref, out_ref):
    """`_writeback_kernel` with the dirty mask as packed uint32 word-bitmask
    lanes (bit pattern carried as int32): the per-word mask is expanded
    in-register — shift each lane across a 32-wide iota and take bit 0 —
    so the DMA engine moves ceil(W/32) mask words per block, not W bytes."""
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0
    w = out_ref.shape[-1]
    words = dirty_ref[...]                               # [1, L] bit lanes
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, words.shape[-1], 32), 2)
    bits = (words[:, :, None] >> shifts) & 1             # arithmetic >> is
    sel = (bits.reshape(1, -1)[:, :w] != 0) & valid      # bit-exact after &1
    first = (i == 0) | (idx_ref[i] != idx_ref[jnp.maximum(i - 1, 0)])
    base = jnp.where(first, l2_ref[...], out_ref[...])
    out_ref[...] = jnp.where(sel, row_ref[...], base)


@functools.partial(jax.jit, static_argnames=("interpret",))
def drain_writeback_pallas(l2: jnp.ndarray, rows: jnp.ndarray,
                           dirty: jnp.ndarray, indices: jnp.ndarray,
                           *, interpret: bool = False) -> jnp.ndarray:
    """Masked scatter-merge of drained blocks into the L2 bank (the sFIFO
    drain writeback, §2.2/§4.2): out = l2 with rows[i] merged into block
    indices[i] under the per-word dirty mask.

    Scatter twin of `selective_flush_pallas`: the drained-block index list
    is scalar-prefetched so both the *input* L2 block and the *output* block
    of each grid step are selected dynamically by the DMA engine, and the L2
    bank is input/output-aliased so untouched blocks stay in place.  The
    sequential grid gives deterministic last-writer-wins merging for
    duplicate indices (same order as the jnp reference).

    l2 [n_blocks, W]; rows [m, W]; dirty [m, W] bool OR [m, ceil(W/32)]
    packed uint32 word-bitmask rows (DESIGN.md §8 — expanded in-kernel by
    `_writeback_kernel_packed`); indices [m] int32 (-1 pad entries write
    nothing).  Returns the merged [n_blocks, W] bank."""
    n_blocks, block_size = l2.shape
    m = indices.shape[0]
    packed = dirty.dtype != jnp.bool_
    safe = jnp.where((indices >= 0) & (indices < n_blocks), indices, -1)
    # group duplicate destinations into consecutive grid steps; the sort is
    # stable, so within a destination the original (priority) order survives
    order = jnp.argsort(safe, stable=True)
    safe = safe[order]
    rows = rows[order]
    dirty = dirty[order]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m,),
        in_specs=[
            # pad entries (-1) clamp to block 0; the kernel's valid flag
            # turns the write into a copy of that block onto itself
            pl.BlockSpec((1, block_size),
                         lambda i, idx: (jnp.maximum(idx[i], 0), 0)),
            pl.BlockSpec((1, block_size), lambda i, idx: (i, 0)),
            pl.BlockSpec((1, dirty.shape[-1]), lambda i, idx: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_size),
                               lambda i, idx: (jnp.maximum(idx[i], 0), 0)),
    )
    return pl.pallas_call(
        _writeback_kernel_packed if packed else _writeback_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks, block_size), l2.dtype),
        input_output_aliases={1: 0},   # l2 bank updated in place
        interpret=interpret,
    )(safe, l2, rows, dirty.astype(jnp.int32))
