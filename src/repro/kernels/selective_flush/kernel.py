"""Pallas TPU kernel: selective flush = gather-compact of dirty blocks.

This is the TPU-native realization of the paper's selective-flush (§4.2):
instead of a GPU L1 walking its sFIFO and writing blocks back one by one,
the TPU owner gathers exactly the dirty parameter/state blocks named by the
sFIFO into a contiguous staging buffer — which then feeds one small
collective (the "writeback to global scope").

TPU-idiomatic pattern: the dirty-block index list is *scalar-prefetched*
(PrefetchScalarGridSpec) so the BlockSpec index_map can select a dynamic HBM
block per grid step — dynamic gather without scatter/gather instructions,
driven entirely by the DMA engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flush_kernel(idx_ref, bank_ref, out_ref):
    i = pl.program_id(0)
    valid = idx_ref[i] >= 0

    @pl.when(valid)
    def _copy():
        out_ref[...] = bank_ref[...]

    @pl.when(jnp.logical_not(valid))
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_flush_pallas(bank: jnp.ndarray, indices: jnp.ndarray,
                           *, interpret: bool = False) -> jnp.ndarray:
    """bank [n_blocks, block_size], indices [max_dirty] int32 (-1 pad)
    -> [max_dirty, block_size]."""
    n_blocks, block_size = bank.shape
    max_dirty = indices.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(max_dirty,),
        in_specs=[
            # clamp pad entries (-1) in the index_map; the kernel zeroes them
            pl.BlockSpec((1, block_size),
                         lambda i, idx: (jnp.maximum(idx[i], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, block_size), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _flush_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((max_dirty, block_size), bank.dtype),
        interpret=interpret,
    )(indices, bank)
