"""Pure-jnp oracle for the selective-flush gather-compact."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitmask


def selective_flush_ref(bank: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """out[i] = bank[indices[i]] for indices[i] >= 0 else zeros.

    bank: [n_blocks, block_size]; indices: [max_dirty] int32 (-1 padded).
    Returns [max_dirty, block_size] in bank.dtype."""
    safe = jnp.clip(indices, 0, bank.shape[0] - 1)
    out = bank[safe]
    return jnp.where((indices >= 0)[:, None], out, jnp.zeros_like(out))


def selective_apply_ref(bank: jnp.ndarray, updates: jnp.ndarray,
                        indices: jnp.ndarray) -> jnp.ndarray:
    """Inverse: bank[indices[i]] = updates[i] for valid i (scatter)."""
    valid = indices >= 0
    safe = jnp.where(valid, indices, bank.shape[0])  # dropped
    return bank.at[safe].set(jnp.where(valid[:, None], updates,
                                       jnp.zeros_like(updates)), mode="drop")


def drain_writeback_ref(l2: jnp.ndarray, rows: jnp.ndarray,
                        dirty: jnp.ndarray, indices: jnp.ndarray
                        ) -> jnp.ndarray:
    """Masked scatter-merge of drained cache blocks into the L2 bank.

    l2 [n_blocks, W]; rows [m, W] drained L1 block values; dirty [m, W] bool
    per-word writeback mask; indices [m] int32 destination block ids (-1 or
    >= n_blocks entries are dropped).

    out[b, w] = rows[i, w] for the *last* list entry i with indices[i] == b
    and dirty[i, w]; untouched words keep their l2 value.  List order is the
    priority (later wins), matching the serial engine's ascending drain
    order, so block-level false sharing merges deterministically."""
    nb = l2.shape[0]
    m = indices.shape[0]
    g = (indices >= 0) & (indices < nb)
    if dirty.dtype != jnp.bool_:       # packed uint32 word-bitmask rows
        dirty = bitmask.unpack(dirty, l2.shape[1])
    sel = dirty & g[:, None]
    prio = jnp.where(sel, jnp.arange(1, m + 1, dtype=jnp.int32)[:, None], 0)
    owner = jnp.zeros(l2.shape, jnp.int32).at[
        jnp.where(g, indices, nb)].max(prio, mode="drop")
    src = jnp.clip(owner - 1, 0)
    vals = rows[src, jnp.arange(l2.shape[1])[None, :]]
    return jnp.where(owner > 0, vals, l2)
