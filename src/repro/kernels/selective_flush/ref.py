"""Pure-jnp oracle for the selective-flush gather-compact."""
from __future__ import annotations

import jax.numpy as jnp


def selective_flush_ref(bank: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """out[i] = bank[indices[i]] for indices[i] >= 0 else zeros.

    bank: [n_blocks, block_size]; indices: [max_dirty] int32 (-1 padded).
    Returns [max_dirty, block_size] in bank.dtype."""
    safe = jnp.clip(indices, 0, bank.shape[0] - 1)
    out = bank[safe]
    return jnp.where((indices >= 0)[:, None], out, jnp.zeros_like(out))


def selective_apply_ref(bank: jnp.ndarray, updates: jnp.ndarray,
                        indices: jnp.ndarray) -> jnp.ndarray:
    """Inverse: bank[indices[i]] = updates[i] for valid i (scatter)."""
    valid = indices >= 0
    safe = jnp.where(valid, indices, bank.shape[0])  # dropped
    return bank.at[safe].set(jnp.where(valid[:, None], updates,
                                       jnp.zeros_like(updates)), mode="drop")
