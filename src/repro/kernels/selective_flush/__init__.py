from repro.kernels.selective_flush.ops import (selective_flush,  # noqa: F401
                                               selective_apply,
                                               drain_writeback)
