from repro.kernels.selective_flush.ops import selective_flush, selective_apply  # noqa: F401
