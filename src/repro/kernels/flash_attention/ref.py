"""Pure-jnp oracle: causal GQA attention (naive O(S^2) materialization)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True, scale: float | None = None
                  ) -> jnp.ndarray:
    """q [B, Hq, S, D]; k, v [B, Hkv, S, D]; Hq % Hkv == 0.
    Returns [B, Hq, S, D] in q.dtype."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = _softmax(logits)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
