"""Pallas TPU kernel: causal GQA flash attention (forward).

TPU adaptation notes (DESIGN.md §2): the FlashAttention recurrence is
re-tiled for the MXU and VMEM instead of warps/shared memory —
(block_q x d) @ (d x block_k) contractions with d and block sizes padded to
multiples of 128/8 so the systolic array is fully fed.  The online-softmax
running state (m, l, acc) lives in VMEM scratch and is carried across the
innermost (kv) grid dimension, which Pallas TPU executes sequentially per
(batch, head, q-block) — exactly the semantics flash needs.

Causal skipping: fully-masked kv blocks are skipped with pl.when on the
block index (their DMAs still issue; acceptable at validation scale and a
known further optimization on real hardware — see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool, block_q: int, block_k: int):
    i = pl.program_id(2)           # q block
    j = pl.program_id(3)           # kv block
    n_k = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # with causal masking, kv blocks strictly above the diagonal contribute 0
    needed = (jnp.asarray(True) if not causal
              else (j * block_k <= i * block_q + block_q - 1))

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)               # [bk, d]
        s = q @ k.T                                       # [bq, bk]
        if causal:
            rows = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]                               # [bq, 1]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(j == n_k - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None, block_q: int = 128,
                           block_k: int = 128, interpret: bool = False):
    """q [B, Hq, Sq, D]; k, v [B, Hkv, Sk, D] -> [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)

    grid = (b, hq, sq // block_q, sk // block_k)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, i, j, g=group: (bb, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, h, i, j, g=group: (bb, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
