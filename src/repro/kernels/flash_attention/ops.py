"""Jit'd public wrapper for flash attention with backend dispatch.

Training uses the differentiable blockwise-jnp attention in
repro/models/layers.py; this kernel is the serving / TPU fast path and the
oracle-validated Pallas artifact."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention import ref


def flash_attention(q, k, v, *, causal: bool = True,
                    scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    use_pallas: bool = True,
                    interpret: bool | None = None) -> jnp.ndarray:
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=causal, scale=scale)
    if interpret is None:
        interpret = default_interpret()
    return flash_attention_pallas(q, k, v, causal=causal, scale=scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
