from repro.kernels.fused_turn.ops import (TripPlan,  # noqa: F401
                                          plane_commit,
                                          trip_plan)
