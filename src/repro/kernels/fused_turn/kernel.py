"""Pallas TPU kernels for the fused batched trip (DESIGN.md §12).

Two kernels, matching the two fusion surfaces of `ref.py`:

  * `trip_plan_pallas` — the whole select-commuting-pops decision in ONE
    kernel invocation: masked first-argmin reductions, the clock-lex
    batch rule with the future-first-remote fence, and (when the
    workload declares the remote-batching capability) the n×n address
    dedup of the co-schedulable remote batch.  Everything lives in VMEM
    as [1, n] rows; reductions are branch-free min/where chains so the
    VPU never leaves the kernel for a scheduling decision.

  * `plane_commit_pallas` — the packed wvalid/wdirty plane scatter of
    `protocol.b_store_word`/`b_load` fused into one pass per lane: grid
    over lanes, the (lane, block) row selected by a scalar-prefetched
    index map (the `selective_flush` idiom), and the single-bit update
    expanded IN REGISTER from the uint32 word-bitmask — build the lane
    mask with a `broadcasted_iota` compare against `o >> 5` and OR the
    `1 << (o & 31)` pattern in, reading the pre-op bit from the same
    register (`core/bitmask.py` semantics; no unpacked plane ever
    materializes).  Both planes are input/output-aliased so untouched
    blocks stay in place.

The jnp references in `ref.py` are the CPU fast path AND the oracle the
interpret-mode unit tests pin these kernels against
(tests/test_kernels.py) — same discipline as `selective_flush`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fused_turn.ref import TripPlan

# ref.BIG as a Python scalar: Pallas kernels cannot capture device
# constants, and a literal folds into the kernel body
BIG = 3e38


def _first_min(vals, mask, idx, n):
    """(min, first-argmin-index) over masked lanes — first index holding
    the min, 0 when the mask is empty (matching `jnp.argmin` over a
    BIG-filled row, the `_batched_trip` convention; assumes real clocks
    stay < BIG, which f32 cycle accumulators do)."""
    m = jnp.min(jnp.where(mask, vals, BIG))
    j = jnp.min(jnp.where(mask & (vals == m), idx, n))
    return m, jnp.where(j == n, 0, j).astype(jnp.int32)


def _plan_kernel(clocks_ref, can_l_ref, can_r_ref, bound_ref, raddr_ref,
                 hor_ref, lmask_ref, rmask_ref, wg_ref, *, remote_cap):
    n = clocks_ref.shape[-1]
    idx = lax.broadcasted_iota(jnp.int32, (1, n), 1)
    clocks = clocks_ref[...]
    can_l = can_l_ref[...] != 0
    can_r = can_r_ref[...] != 0
    hor = hor_ref[0, 0]

    cand = can_l | can_r
    _, wg = _first_min(clocks, cand, idx, n)
    ms, js = _first_min(clocks, can_r, idx, n)
    fence = jnp.min(jnp.where(can_l, clocks + bound_ref[...], BIG))
    lex = (clocks < ms) | ((clocks == ms) & (idx < js))
    batch = can_l & lex & (clocks <= fence) & (clocks < hor)
    any_b = jnp.any(batch)
    lmask = batch | (~any_b & (idx == wg) & can_l)

    if remote_cap:
        ml, jl = _first_min(clocks, can_l, idx, n)
        lexr = (clocks < ml) | ((clocks == ml) & (idx < jl))
        r0 = can_r & lexr & (clocks < hor)
        raddr = raddr_ref[...]
        ri, rj = raddr.reshape(n, 1), raddr.reshape(1, n)
        ci, cj = clocks.reshape(n, 1), clocks.reshape(1, n)
        ii, ij = idx.reshape(n, 1), idx.reshape(1, n)
        r0i, r0j = r0.reshape(n, 1), r0.reshape(1, n)
        collide = r0i & r0j & (ri == rj)
        earlier = (cj < ci) | ((cj == ci) & (ij < ii))
        rmask = r0 & ~jnp.any(collide & earlier, axis=1).reshape(1, n)
    else:
        rmask = jnp.zeros((1, n), bool)

    lmask_ref[...] = lmask.astype(jnp.int32)
    rmask_ref[...] = rmask.astype(jnp.int32)
    wg_ref[...] = jnp.full((1, 1), wg, jnp.int32)


@functools.partial(jax.jit, static_argnames=("remote_cap", "interpret"))
def trip_plan_pallas(clocks, can_l, can_r, bound, raddr, horizon,
                     *, remote_cap: bool, interpret: bool = False
                     ) -> TripPlan:
    """One-kernel batched-trip plan; bitwise `ref.trip_plan_ref`.

    Scalar `horizon` must be a concrete value (pass BIG for the plain
    engines' no-fence trips); `raddr` is ignored when remote_cap=False
    (pass zeros)."""
    n = clocks.shape[0]
    row = lambda x, dt: jnp.asarray(x, dt).reshape(1, n)
    hor = jnp.asarray(horizon, jnp.float32).reshape(1, 1)
    lmask, rmask, wg = pl.pallas_call(
        functools.partial(_plan_kernel, remote_cap=remote_cap),
        out_shape=(jax.ShapeDtypeStruct((1, n), jnp.int32),
                   jax.ShapeDtypeStruct((1, n), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)),
        interpret=interpret,
    )(row(clocks, jnp.float32), row(can_l, jnp.int32), row(can_r, jnp.int32),
      row(bound, jnp.float32), row(raddr, jnp.int32), hor)
    return TripPlan(lmask=lmask[0] != 0, rmask=rmask[0] != 0, wg=wg[0, 0])


def _commit_kernel(b_ref, o_ref, sv_ref, sd_ref, wv_ref, wd_ref,
                   wv_out, wd_out, wasv_ref, wasd_ref):
    i = pl.program_id(0)
    L = wv_ref.shape[-1]
    o = o_ref[i]
    # in-register uint32 bitmask expansion (core/bitmask.py semantics):
    # word o lives in lane o >> 5, bit o & 31 — one [1, L] pattern row,
    # no unpacked plane
    lanes = lax.broadcasted_iota(jnp.int32, (1, L), 1)
    bit = jnp.uint32(1) << (o.astype(jnp.uint32) & jnp.uint32(31))
    pattern = jnp.where(lanes == (o >> 5), bit, jnp.uint32(0))
    rv = wv_ref[0, 0, :].reshape(1, L)
    rd = wd_ref[0, 0, :].reshape(1, L)
    wasv_ref[0] = jnp.any((rv & pattern) != 0).astype(jnp.int32)
    wasd_ref[0] = jnp.any((rd & pattern) != 0).astype(jnp.int32)
    mv = jnp.where(sv_ref[i] != 0, pattern, jnp.uint32(0))
    md = jnp.where(sd_ref[i] != 0, pattern, jnp.uint32(0))
    wv_out[0, 0, :] = (rv | mv).reshape(L)
    wd_out[0, 0, :] = (rd | md).reshape(L)


@functools.partial(jax.jit, static_argnames=("interpret",))
def plane_commit_pallas(wvalid, wdirty, b, o, set_valid, set_dirty,
                        *, interpret: bool = False):
    """Fused packed-plane commit; bitwise `ref.plane_commit_ref` on the
    packed layout.  wvalid/wdirty [n, nb, L] uint32; b/o [n] i32;
    set_valid/set_dirty [n] bool.  Grid over lanes, the target (lane,
    block) row DMA-selected by the scalar-prefetched block index (every
    (lane, b) pair is distinct, so steps never collide); both planes
    aliased in place.  Returns (wvalid', wdirty', was_valid, was_dirty)."""
    n, nb, L = wvalid.shape
    b32 = jnp.clip(jnp.asarray(b, jnp.int32), 0, nb - 1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, 1, L), lambda i, b, o, sv, sd: (i, b[i], 0)),
            pl.BlockSpec((1, 1, L), lambda i, b, o, sv, sd: (i, b[i], 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, L), lambda i, b, o, sv, sd: (i, b[i], 0)),
            pl.BlockSpec((1, 1, L), lambda i, b, o, sv, sd: (i, b[i], 0)),
            pl.BlockSpec((1,), lambda i, b, o, sv, sd: (i,)),
            pl.BlockSpec((1,), lambda i, b, o, sv, sd: (i,)),
        ),
    )
    wv2, wd2, wasv, wasd = pl.pallas_call(
        _commit_kernel,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((n, nb, L), jnp.uint32),
                   jax.ShapeDtypeStruct((n, nb, L), jnp.uint32),
                   jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)),
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(b32, jnp.asarray(o, jnp.int32),
      jnp.asarray(set_valid, jnp.int32), jnp.asarray(set_dirty, jnp.int32),
      wvalid, wdirty)
    return wv2, wd2, wasv != 0, wasd != 0
