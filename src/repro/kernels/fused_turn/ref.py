"""Pure-jnp oracle for the fused-turn megakernel (DESIGN.md §12).

Two fusion surfaces, each with the exact semantics of the code it
replaces — the reference IS the pre-fusion `_batched_trip` path, so the
cross-engine equivalence suites pin the kernel against the very math the
batched engine has always run:

  * `trip_plan_ref` — the select-commuting-pops decision of
    `harness._batched_trip`: local batch mask (clock-lex against every
    remote candidate + the future-first-remote fence), the co-schedulable
    remote batch (clock-lex against every local candidate, address
    dedup), and the serial-fallback agent.  The formulas are transcribed
    verbatim; only the *execution* structure differs (the fused engine
    runs ONE masked `local_turn` covering both the batch and the
    serial-local fallback — the equivalence argument is in DESIGN.md
    §12).
  * `plane_commit_ref` — the metadata-plane front-end of
    `protocol.b_load`/`b_store_word`: read the pre-op wvalid/wdirty bits
    (the trace classification of `ops.load`/`ops.store` — OC_HIT vs
    OC_MISS) and OR in the new bits, both planes in one pass.  Packed
    (uint32 word-bitmask, DESIGN.md §8) and boolean layouts are told
    apart by dtype, like `selective_flush.drain_writeback`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import bitmask

BIG = jnp.float32(3e38)


class TripPlan(NamedTuple):
    """One batched-trip scheduling decision (all lanes, no state)."""
    lmask: jnp.ndarray   # [n] bool  agents whose local turn executes
    rmask: jnp.ndarray   # [n] bool  co-schedulable remote batch (only
    #                      consulted when lmask is all-False)
    wg: jnp.ndarray      # []  i32   serial-fallback agent (first argmin)


def trip_plan_ref(clocks, can_l, can_r, bound, raddr, horizon) -> TripPlan:
    """The `_batched_trip` selection math, verbatim.

    clocks [n] f32 per-agent cycle clocks; can_l/can_r [n] bool readiness;
    bound [n] f32 `remote_bound` lower bounds; raddr [n] i32 next-remote
    target addresses (pass None when the workload has no remote-batching
    capability — the dedup math is skipped statically); horizon [] f32 or
    None — the elastic event fence (None compiles the masking away).

    lmask = batch                      when the batch is nonempty
          = one_hot(wg) & can_l[wg]    otherwise (the serial local case)
    rmask = the address-deduped remote batch (raw — DESIGN.md §12 proves
            it is empty whenever lmask is nonempty, so no extra masking)
    """
    n = clocks.shape[0]
    wgs = jnp.arange(n, dtype=jnp.int32)
    cand = can_l | can_r
    masked = jnp.where(cand, clocks, BIG)
    wg = jnp.argmin(masked).astype(jnp.int32)
    sclk = jnp.where(can_r, clocks, BIG)
    ms = jnp.min(sclk)
    js = jnp.argmin(sclk).astype(jnp.int32)
    fence = jnp.min(jnp.where(can_l, clocks + bound, BIG))
    lex = (clocks < ms) | ((clocks == ms) & (wgs < js))
    batch = can_l & lex & (clocks <= fence)
    if horizon is not None:
        batch = batch & (clocks < horizon)
    any_b = jnp.any(batch)
    # serial fallback folded into the SAME masked local turn: when the
    # batch is empty and the first-argmin candidate has a local turn,
    # one-hot it (≡ `_serial_turn`'s local branch — DESIGN.md §12)
    lmask = batch | (~any_b & can_l[wg] & (wgs == wg))

    if raddr is None:
        rmask = jnp.zeros((n,), bool)
        return TripPlan(lmask=lmask, rmask=rmask, wg=wg)

    # remote candidates preceding every local candidate (lex mirrored),
    # minus address collisions with an earlier (clock, idx) lane —
    # `_batched_trip.do_remote_or_serial`, verbatim
    lclk = jnp.where(can_l, clocks, BIG)
    ml = jnp.min(lclk)
    jl = jnp.argmin(lclk).astype(jnp.int32)
    lexr = (clocks < ml) | ((clocks == ml) & (wgs < jl))
    r0 = can_r & lexr
    if horizon is not None:
        r0 = r0 & (clocks < horizon)
    collide = r0[:, None] & r0[None, :] & (raddr[:, None] == raddr[None, :])
    earlier = (clocks[None, :] < clocks[:, None]) \
        | ((clocks[None, :] == clocks[:, None]) & (wgs[None, :] < wgs[:, None]))
    rmask = r0 & ~jnp.any(collide & earlier, axis=1)
    return TripPlan(lmask=lmask, rmask=rmask, wg=wg)


def plane_commit_ref(wvalid, wdirty, b, o, set_valid, set_dirty):
    """Fused wvalid/wdirty front-end: pre-op bit reads + per-lane flag OR,
    both planes in one pass.

    wvalid/wdirty [n, nb, L] uint32 packed or [n, nb, W] bool; b/o [n] i32
    per-lane (block, word-offset) targets; set_valid/set_dirty [n] bool OR
    masks (set_dirty=None skips the wdirty update statically — the
    `b_load` shape).  Returns (wvalid', wdirty', was_valid, was_dirty):
    the was_* bits are the PRE-update flags — exactly the OC_HIT/OC_MISS
    (load) and write-combining (store) classification bits of
    `ops._l1_state`.  (lane, b) pairs are distinct by construction (lane
    is the cache id), so the scatters are safe."""
    n = wvalid.shape[0]
    lane = jnp.arange(n)
    packed = wvalid.dtype != jnp.bool_
    if packed:
        w = bitmask.word_index(o)
        bit = bitmask.word_bit(o)
        wv = wvalid[lane, b, w]
        wd = wdirty[lane, b, w]
        was_valid = (wv & bit) != 0
        was_dirty = (wd & bit) != 0
        mv = jnp.where(jnp.asarray(set_valid, bool), bit, jnp.uint32(0))
        wvalid = wvalid.at[lane, b, w].set(wv | mv)
        if set_dirty is not None:
            md = jnp.where(jnp.asarray(set_dirty, bool), bit, jnp.uint32(0))
            wdirty = wdirty.at[lane, b, w].set(wd | md)
        return wvalid, wdirty, was_valid, was_dirty
    was_valid = wvalid[lane, b, o]
    was_dirty = wdirty[lane, b, o]
    wvalid = wvalid.at[lane, b, o].set(was_valid | set_valid)
    if set_dirty is not None:
        wdirty = wdirty.at[lane, b, o].set(was_dirty | set_dirty)
    return wvalid, wdirty, was_valid, was_dirty
