"""Public entry points for the fused-turn kernels (DESIGN.md §12).

Same dispatch discipline as `selective_flush.drain_writeback`: the Pallas
kernels run when the process-wide `kernel_mode()` says so (TPU, or forced
interpret for debugging); on CPU the jnp references in `ref.py` are both
the fast path and the oracle — interpret-mode Pallas is reserved for the
kernel equivalence tests, never a silent benchmark path
(`kernels/common.py`).

`plane_commit` additionally falls back to the reference for the boolean
(REPRO_NO_PACK=1) metadata layout: the packed uint32 planes are the TPU
production layout (DESIGN.md §8), the boolean planes a CPU escape hatch.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.fused_turn import ref
from repro.kernels.fused_turn.kernel import (plane_commit_pallas,
                                             trip_plan_pallas)
from repro.kernels.fused_turn.ref import BIG, TripPlan  # noqa: F401


def trip_plan(clocks, can_l, can_r, bound, raddr, horizon, *,
              remote_cap: bool, use_pallas: bool | None = None,
              interpret: bool | None = None) -> TripPlan:
    """One batched-trip scheduling decision (select-commuting-pops +
    remote co-schedule dedup) — `ref.trip_plan_ref`'s contract.  `raddr`
    may be None when remote_cap=False; `horizon` None means no event
    fence (the plain engines)."""
    if use_pallas is None:
        use_pallas = common.use_pallas()
    if not use_pallas:
        return ref.trip_plan_ref(clocks, can_l, can_r, bound,
                                 raddr if remote_cap else None, horizon)
    if interpret is None:
        interpret = common.interpret()
    if raddr is None:
        raddr = jnp.zeros_like(clocks, jnp.int32)
    hor = BIG if horizon is None else horizon
    return trip_plan_pallas(clocks, can_l, can_r, bound, raddr, hor,
                            remote_cap=remote_cap, interpret=interpret)


def plane_commit(wvalid, wdirty, b, o, set_valid, set_dirty, *,
                 use_pallas: bool | None = None,
                 interpret: bool | None = None):
    """Fused metadata-plane front-end: pre-op wvalid/wdirty bit reads +
    per-lane flag OR, one pass over both planes.  Returns
    (wvalid', wdirty', was_valid, was_dirty) — see `ref.plane_commit_ref`.
    `set_dirty=None` statically skips the wdirty update (`b_load`)."""
    if use_pallas is None:
        use_pallas = common.use_pallas()
    # the Pallas kernel targets the packed production layout only; the
    # boolean escape-hatch layout (REPRO_NO_PACK=1) always refs
    if not use_pallas or wvalid.dtype == jnp.bool_ or set_dirty is None:
        return ref.plane_commit_ref(wvalid, wdirty, b, o,
                                    set_valid, set_dirty)
    if interpret is None:
        interpret = common.interpret()
    return plane_commit_pallas(wvalid, wdirty, b, o, set_valid, set_dirty,
                               interpret=interpret)
