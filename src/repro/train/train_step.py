"""Train step: value_and_grad + microbatch gradient accumulation + optimizer.

Microbatching reshapes [GB, ...] -> [n_micro, MB, ...] and lax.scans the
forward/backward, accumulating f32 gradients — this is what bounds
activation memory for the 123B/671B train_4k cells (the accumulation loop
is the standard distributed-optimization trick; remat happens inside the
model's layer scan)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.registry import Model
from repro.optim.optimizers import apply_updates
from repro.sharding import shard


def _split_micro(batch, n_micro):
    def f(x):
        gb = x.shape[0]
        assert gb % n_micro == 0, (gb, n_micro)
        return x.reshape(n_micro, gb // n_micro, *x.shape[1:])
    return jax.tree.map(f, batch)


def make_train_step(model: Model, opt_init, opt_update,
                    n_micro: Optional[int] = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Pure; jit/pjit it with the desired shardings."""

    def loss_fn(params, micro_batch):
        loss, metrics = model.loss(params, micro_batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if n_micro and n_micro > 1:
            micro = _split_micro(batch, n_micro)

            def accum(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n_micro,
                    g_acc, grads)
                return (g_acc, loss_acc + loss / n_micro), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = lax.scan(accum, (g0, jnp.float32(0.0)), micro)
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
        updates, opt_state, gnorm = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        out_metrics = {"loss": metrics.get("loss", 0.0), "gnorm": gnorm}
        return params, opt_state, out_metrics

    return train_step
