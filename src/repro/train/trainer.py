"""Trainer: pjit'd step with explicit shardings, synthetic pipeline,
fault-tolerant loop (checkpoint/restart, straggler detection, heartbeat),
and optional sRSP-style cross-pod delta sync in local-SGD mode."""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import TokenPipeline
from repro.models.registry import build
from repro.optim import make_optimizer
from repro.runtime import checkpoint as CK
from repro.runtime.fault import FaultTolerantRunner, Heartbeat, StepTimer
from repro.sharding import param_shardings, param_specs, use_mesh
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq: int = 256
    lr: float = 3e-4
    warmup: int = 20
    seed: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 200
    log_every: int = 10
    microbatch: Optional[int] = None


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, mesh=None):
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.model = build(cfg)
        opt_init, opt_update = make_optimizer(
            cfg.optimizer, lr=tcfg.lr, warmup=tcfg.warmup,
            total_steps=max(tcfg.steps, 1))
        n_micro = (tcfg.batch // tcfg.microbatch
                   if tcfg.microbatch else None)
        self._step_fn = make_train_step(self.model, opt_init, opt_update,
                                        n_micro)
        self._opt_init = opt_init
        self.metrics_log: list = []

    def init_state(self):
        with use_mesh(self.mesh):
            key = jax.random.PRNGKey(self.tcfg.seed)
            if self.mesh is not None:
                p_sh = param_shardings(
                    jax.eval_shape(self.model.init, key), self.mesh)
                params = jax.jit(self.model.init, out_shardings=p_sh)(key)
                o_sh = param_shardings(
                    jax.eval_shape(self._opt_init, params), self.mesh)
                opt = jax.jit(self._opt_init, out_shardings=o_sh)(params)
            else:
                params = jax.jit(self.model.init)(key)
                opt = jax.jit(self._opt_init)(params)
        return {"params": params, "opt": opt}

    def jitted_step(self):
        if self.mesh is None:
            return jax.jit(self._step_fn)
        with use_mesh(self.mesh):
            params_abs = jax.eval_shape(self.model.init,
                                        jax.random.PRNGKey(0))
            p_sh = param_shardings(params_abs, self.mesh)
            o_sh = param_shardings(
                jax.eval_shape(self._opt_init, params_abs), self.mesh)
            return jax.jit(self._step_fn,
                           in_shardings=(p_sh, o_sh, None),
                           out_shardings=(p_sh, o_sh, None))

    def run(self, fail_at: Optional[int] = None):
        """Train; `fail_at` injects one failure (fault-tolerance tests)."""
        cfg, tcfg = self.cfg, self.tcfg
        extras = {}
        if cfg.family == "vlm":
            extras["patch_embeds"] = (cfg.n_patches, 1024)
        if cfg.family == "encdec":
            extras["src_embeds"] = (tcfg.seq, 1024)
        pipe = TokenPipeline(cfg.vocab, tcfg.batch, tcfg.seq,
                             seed=tcfg.seed, extras=extras)
        step_jit = self.jitted_step()
        state = self.init_state()
        timer = StepTimer()
        hb = (Heartbeat(os.path.join(tcfg.ckpt_dir, "heartbeat"))
              if tcfg.ckpt_dir else None)
        failed = {"done": False}

        def one_step(st, i):
            if fail_at is not None and i == fail_at and not failed["done"]:
                failed["done"] = True
                raise RuntimeError("injected node failure")
            batch = next(pipe)
            with use_mesh(self.mesh):
                params, opt, metrics = step_jit(st["params"], st["opt"], batch)
            if hb:
                hb.beat(i)
            return {"params": params, "opt": opt, "_metrics": metrics}

        def on_step(i, st, dt, straggler):
            if i % tcfg.log_every == 0 or straggler:
                m = jax.tree.map(float, st.get("_metrics", {}))
                m.update(step=i, dt=round(dt, 3), straggler=straggler)
                self.metrics_log.append(m)

        if tcfg.ckpt_dir:
            runner = FaultTolerantRunner(tcfg.ckpt_dir,
                                         save_every=tcfg.ckpt_every)
            def save_fn(step, st):
                CK.save_checkpoint(tcfg.ckpt_dir, step,
                                   {"params": st["params"], "opt": st["opt"]})
            def restore_fn(path, st):
                step, restored = CK.restore_checkpoint(
                    path, {"params": st["params"], "opt": st["opt"]})
                restored["_metrics"] = {}
                return step, restored
            runner.save_fn = save_fn
            runner.restore_fn = restore_fn
            _, state = runner.run(state, one_step, tcfg.steps,
                                  on_step=on_step)
            self.restarts = runner.restarts
        else:
            for i in range(tcfg.steps):
                timer.start()
                state = one_step(state, i)
                dt, s = timer.stop()
                on_step(i, state, dt, s)
            self.restarts = 0
        pipe.close()
        return state
