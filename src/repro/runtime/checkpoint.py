"""Checkpointing: per-leaf .npy + JSON manifest, atomic directory rename,
optional async (background-thread) save, and reshard-on-restore — restoring
onto a different mesh/sharding than the one that saved is the elastic-
rescale path (runtime/elastic.py, tested in tests/test_runtime.py).

At real scale each host writes only its addressable shards; here the full
array is gathered (single host) — the manifest format is host-count
agnostic, which is what restart/elastic correctness depends on."""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out.append((path, leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    *, async_save: bool = False,
                    keep: int = 3) -> Optional[threading.Thread]:
    """state: arbitrary pytree (e.g. {'params':…, 'opt':…})."""
    flat, _ = _flatten(state)
    host = [(p, np.asarray(x)) for p, x in flat]

    def write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "leaves": []}
        for i, (path, arr) in enumerate(host):
            np.save(os.path.join(tmp, f"{i}.npy"), arr)
            manifest["leaves"].append(
                {"i": i, "path": path, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def restore_checkpoint(path: str, like: Any, *, shardings: Any = None):
    """Restore into the structure of `like`; device_put with `shardings`
    (pytree of NamedSharding or None) — resharding happens here."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _flatten(like)
    by_path = {rec["path"]: rec for rec in manifest["leaves"]}
    leaves = []
    sh_flat = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: s is None or hasattr(s, "spec"))
        if shardings is not None else [None] * len(flat_like))
    for (leaf_path, leaf), sh in zip(flat_like, sh_flat):
        rec = by_path.get(leaf_path)
        if rec is None:
            raise KeyError(f"checkpoint missing leaf {leaf_path!r}")
        arr = np.load(os.path.join(path, f"{rec['i']}.npy"))
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jnp.asarray(arr))
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, leaves)
