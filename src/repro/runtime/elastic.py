"""Elastic rescale: rebuild a mesh from whatever devices survive and restore
a checkpoint onto it.

The checkpoint format is sharding-agnostic (full logical arrays), so a
restore onto a different (data, model) grid is just device_put with the new
shardings — `reshard_restore` below.  Policy: keep the model axis as large
as the layout allows (TP must divide head/ffn dims), give the rest to data."""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.runtime import checkpoint as CK
from repro.sharding import param_shardings


def choose_mesh(n_devices: Optional[int] = None, *, model_divisors=(16, 8, 4, 2, 1),
                max_model: int = 16) -> Mesh:
    devs = jax.devices()
    n = n_devices or len(devs)
    model = 1
    for m in model_divisors:
        if m <= max_model and n % m == 0:
            model = m
            break
    data = n // model
    return Mesh(np.asarray(devs[:n]).reshape(data, model), ("data", "model"))


def reshard_restore(ckpt_path: str, like_state, mesh: Mesh):
    """Restore a checkpoint onto `mesh`, resharding every leaf."""
    with mesh:
        sh = {"params": param_shardings(like_state["params"], mesh),
              "opt": param_shardings(like_state["opt"], mesh)}
        step, state = CK.restore_checkpoint(ckpt_path, like_state,
                                            shardings=sh)
    return step, state
