"""Fault tolerance & straggler mitigation for the training loop.

* StepTimer — rolling step-time stats; flags straggler steps (z-score over a
  window).  At cluster scale the same statistic runs per-host and feeds the
  coordinator's replacement policy; here it drives logging + the grace
  checkpoint.
* FaultTolerantRunner — wraps a step callable: on failure it saves an
  emergency checkpoint and restarts from the latest one, up to max_restarts.
  Injected failures (tests) exercise the same path a preempted TPU host
  would.
* Heartbeat — liveness file other processes / the coordinator can watch.
"""
from __future__ import annotations

import os
import time
from collections import deque
from typing import Callable, Optional

from repro.runtime import checkpoint as CK


class StepTimer:
    def __init__(self, window: int = 50, z_thresh: float = 3.0):
        self.window = deque(maxlen=window)
        self.z_thresh = z_thresh
        self.stragglers = 0
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> tuple[float, bool]:
        dt = time.perf_counter() - self._t0
        is_straggler = False
        if len(self.window) >= 10:
            mean = sum(self.window) / len(self.window)
            var = sum((x - mean) ** 2 for x in self.window) / len(self.window)
            std = max(var ** 0.5, 1e-9)
            if (dt - mean) / std > self.z_thresh:
                is_straggler = True
                self.stragglers += 1
        self.window.append(dt)
        return dt, is_straggler


class Heartbeat:
    """Liveness file other processes / the coordinator can watch.

    Callers that share a machine must use a per-process path (the sweep
    derives one from the pid in the tmpdir) — a fixed filename aliases
    concurrent runs and fools the watcher — and must `stop()` when done
    so a stale file never impersonates a live process."""

    def __init__(self, path: str, interval: float = 10.0):
        self.path = path
        self.interval = interval
        self._last = 0.0

    def beat(self, step: int):
        now = time.time()
        if now - self._last >= self.interval:
            with open(self.path, "w") as f:
                f.write(f"{step} {now}\n")
            self._last = now

    def stop(self):
        """Remove the liveness file (idempotent)."""
        self._last = 0.0
        try:
            os.remove(self.path)
        except OSError:
            pass


class FaultTolerantRunner:
    """run(step_fn) where step_fn(state, step) -> state.  On exception:
    emergency-checkpoint (if possible), restore latest, continue."""

    def __init__(self, ckpt_dir: str, save_every: int = 100,
                 max_restarts: int = 3, restore_fn: Callable = None,
                 save_fn: Callable = None):
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.restore_fn = restore_fn or (
            lambda path, state: CK.restore_checkpoint(path, state))
        self.save_fn = save_fn or (
            lambda step, state: CK.save_checkpoint(self.ckpt_dir, step, state))
        self.restarts = 0

    def run(self, state, step_fn: Callable, n_steps: int, start_step: int = 0,
            on_step: Callable = None):
        os.makedirs(self.ckpt_dir, exist_ok=True)
        step = start_step
        timer = StepTimer()
        while step < n_steps:
            try:
                timer.start()
                state = step_fn(state, step)
                dt, straggler = timer.stop()
                if on_step:
                    on_step(step, state, dt, straggler)
                step += 1
                if step % self.save_every == 0:
                    self.save_fn(step, state)
            except KeyboardInterrupt:
                raise
            except Exception as e:  # noqa: BLE001 — node failure surrogate
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}") from e
                latest = CK.latest_checkpoint(self.ckpt_dir)
                if latest is None:
                    # nothing saved yet: restart from the initial state
                    step = start_step
                    continue
                step, state = self.restore_fn(latest, state)
        return step, state
