"""Trace -> harness adapter (DESIGN.md §13).

The workload harness schedules *turns* (can_local / can_remote /
remote_bound / live); a `RequestTrace` is a flat list of *requests*.
This module is the bridge: it regroups a trace into per-agent streams
plus a cursor, and derives every scheduler predicate the harness needs
from (streams, cursor) alone — so ANY registered workload can be
traffic-driven by embedding an `AgentStreams` + `cursor` in its state
and binding these functions (thin module-level wrappers keep the
Workload hashable).

Driver contract (what a traffic-driven workload's turns must do):

  * an agent's NEXT request is `streams.<col>[i, cursor[i]]`; the turn
    that completes it advances `cursor[i]` by 1 (a retried turn — e.g.
    a lost CAS under fault injection — leaves the cursor in place);
  * requests classify by ownership: `remote[i, j]` is True iff the
    request's key is owned by another agent — the can_local/can_remote
    split is exactly this bit at the cursor;
  * a turn first *waits* for the request: charge
    `max(0, arrival - clock)` idle cycles before the protocol ops, so
    completion latency (completion clock - arrival clock) is measured
    against the arrival process, not the scheduler;
  * every completing turn charges at least `min_turn_cost` compute
    cycles, which is what makes `remote_bound` a sound fence: with
    `lbnr[i, j]` = the run length of local requests starting at j, the
    next remote turn of lane i is at least `lbnr * min_turn_cost`
    cycles away (waits only push it further);
  * `quota[i]` is the retirement-adjustable stream length: elastic
    retire forgives a dead agent's unserved tail (`quota := cursor`),
    admit re-opens one request.  Offered load stays `streams`' full
    length — the self-check reports offered vs completed.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import lax

from repro.traffic import trace as TR

BIG = jnp.float32(3e38)


class AgentStreams(NamedTuple):
    """Per-agent request matrices, [n_agents, m] each (+ [n] quota)."""
    arrival: jnp.ndarray   # f32 arrival clocks, sorted along axis 1
    key: jnp.ndarray       # i32 requested key
    kind: jnp.ndarray      # i32 0=read / 1=write
    remote: jnp.ndarray    # bool key owned by another agent
    lbnr: jnp.ndarray      # i32 local-run length starting here (0 if remote)
    quota: jnp.ndarray     # i32 serviceable stream length per agent


def _local_runs(remote: jnp.ndarray) -> jnp.ndarray:
    """lbnr[i, j]: consecutive local requests starting at column j."""
    def step(nxt, rem_col):
        run = jnp.where(rem_col, 0, nxt + 1)
        return run, run
    _, runs = lax.scan(step, jnp.zeros(remote.shape[0], jnp.int32),
                       remote.T, reverse=True)
    return runs.T


def from_trace(tr: TR.RequestTrace, n_agents: int, m: int) -> AgentStreams:
    """Regroup a flat trace into per-agent streams of exactly `m`
    requests each (the `generate` invariant; ragged traces must be
    padded by the caller).  Pure jnp — callable under jit/vmap."""
    order = jnp.lexsort((tr.arrival, tr.agent))
    take = lambda c: c[order].reshape(n_agents, m)  # noqa: E731
    arrival = take(tr.arrival)
    key = take(tr.key)
    kind = take(tr.kind)
    remote = TR.owner(key, n_agents) \
        != jnp.arange(n_agents, dtype=jnp.int32)[:, None]
    return AgentStreams(arrival=arrival, key=key, kind=kind,
                        remote=remote, lbnr=_local_runs(remote),
                        quota=jnp.full((n_agents,), m, jnp.int32))


def at_cursor(streams: AgentStreams, cursor):
    """(arrival, key, kind, remote) of each agent's next request.
    Exhausted lanes return their LAST request's columns — callers gate
    on `pending` before acting on them."""
    n, m = streams.arrival.shape
    lanes = jnp.arange(n)
    cur = jnp.clip(cursor, 0, m - 1)
    return (streams.arrival[lanes, cur], streams.key[lanes, cur],
            streams.kind[lanes, cur], streams.remote[lanes, cur])


def pending(streams: AgentStreams, cursor):
    """[n] bool: lanes with unserved requests inside their quota."""
    return cursor < streams.quota


def can_local(streams: AgentStreams, cursor):
    _, _, _, rem = at_cursor(streams, cursor)
    return pending(streams, cursor) & ~rem


def can_remote(streams: AgentStreams, cursor):
    _, _, _, rem = at_cursor(streams, cursor)
    return pending(streams, cursor) & rem


def remote_bound(streams: AgentStreams, cursor, min_turn_cost):
    """[n] f32 lower bound on cycles before each lane's next remote turn
    (the harness fence input; BIG for exhausted lanes)."""
    n, m = streams.arrival.shape
    lanes = jnp.arange(n)
    cur = jnp.clip(cursor, 0, m - 1)
    run = streams.lbnr[lanes, cur].astype(jnp.float32)
    return jnp.where(pending(streams, cursor),
                     run * jnp.float32(min_turn_cost), BIG)


def wait_cycles(streams: AgentStreams, cursor, clocks):
    """[n] f32 idle cycles each lane charges before serving its next
    request: the request may not have arrived yet."""
    arr, _, _, _ = at_cursor(streams, cursor)
    return jnp.maximum(arr - clocks, 0.0)


def retire(streams: AgentStreams, cursor, dead) -> AgentStreams:
    """Forgive a dead agent's unserved tail (bitwise identity when
    `dead` is all-False — the elastic contract)."""
    dead = jnp.asarray(dead, bool)
    return streams._replace(
        quota=jnp.where(dead, jnp.minimum(streams.quota, cursor),
                        streams.quota))


def admit(streams: AgentStreams, cursor, join) -> AgentStreams:
    """Re-open one request for a (re-)joining agent, bounded by the
    stream's physical length."""
    join = jnp.asarray(join, bool)
    m = streams.arrival.shape[1]
    return streams._replace(
        quota=jnp.where(join, jnp.minimum(cursor + 1, jnp.int32(m)),
                        streams.quota))
