"""Columnar request traces (DESIGN.md §13).

A `RequestTrace` is four flat, equal-length columns — the minimal wire
format for "who asks for what, when":

    arrival  [M] f32  simulated arrival clock (cycles)
    key      [M] i32  requested key in [0, n_keys)
    kind     [M] i32  0 = read, 1 = write
    agent    [M] i32  issuing agent (front-end shard) in [0, n_agents)

`generate` draws one from the samplers, pure-jnp end to end, so it is
(a) bitwise-replayable from (seed, config) — the sweep's cross-engine
"same trace" guarantee — and (b) vmappable over seeds, which is how the
kv_serving workload replays millions of simulated requests through
`run_batched_many` without materializing per-replica traces on the host.

Key placement is the subsystem's one canonical convention: key `k` is
owned by agent `k % n_agents` (the same interleaving `kv_directory`
uses for buckets).  Each request draws its key from the issuer's OWN
shard with probability `1 - remote_frac` (Zipf over own ranks) and from
the GLOBAL Zipf otherwise — so remote fetches concentrate on the
globally hottest keys, the skew regime the paper's asymmetric-sharing
claim lives or dies on.  Cross-owner requests are forced to reads (a
remote write would need ownership migration — ROADMAP's dynamic
asymmetry item).

`save`/`load` round-trip a trace plus its provenance (config, seed,
shape) through one .npz; `tests/test_traffic.py` pins the round-trip
and the regenerate-equals-saved bitwise property.
"""
from __future__ import annotations

import dataclasses
import json
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.traffic import samplers as S


class RequestTrace(NamedTuple):
    arrival: jnp.ndarray   # [M] f32 sorted-within-agent arrival clocks
    key: jnp.ndarray       # [M] i32 requested key
    kind: jnp.ndarray      # [M] i32 0=read / 1=write
    agent: jnp.ndarray     # [M] i32 issuing agent


def owner(key, n_agents: int):
    """Canonical placement: key k lives on agent k % n_agents."""
    return jnp.mod(jnp.asarray(key, jnp.int32), jnp.int32(n_agents))


def generate(cfg: S.TrafficConfig, n_agents: int, n_keys: int,
             seed) -> RequestTrace:
    """Draw the canonical trace for (cfg, n_agents, n_keys, seed).

    Pure jnp (traced `seed` ok): one PRNG fold per agent, independent
    sub-keys per column.  Rows come out globally sorted by arrival clock
    (ties: agent, then issue order) — a stable canonical order that is
    bitwise-reproducible run to run."""
    if n_keys % n_agents != 0:
        raise ValueError(f"n_keys ({n_keys}) must be a multiple of "
                         f"n_agents ({n_agents}) for the canonical "
                         f"interleaved placement")
    m = cfg.requests_per_agent
    own_ranks = n_keys // n_agents
    root = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))

    def one_agent(a):
        ka = jax.random.fold_in(root, a)
        sub = [jax.random.fold_in(ka, j) for j in range(5)]
        arr = S.arrival_clocks(sub[0], m, cfg)
        gkey = S.zipf_ranks(sub[1], m, n_keys, cfg.zipf_s)
        lrank = S.zipf_ranks(sub[2], m, own_ranks, cfg.zipf_s)
        rem = S.remote_draws(sub[3], m, cfg.remote_frac)
        wr = S.request_kinds(sub[4], m, cfg.write_frac)
        key = jnp.where(rem, gkey, a + lrank * n_agents)
        kind = jnp.where(owner(key, n_agents) == a, wr, 0)
        return arr, key, kind

    lanes = jnp.arange(n_agents, dtype=jnp.int32)
    arr, key, kind = jax.vmap(one_agent)(lanes)
    agent = jnp.broadcast_to(lanes[:, None], (n_agents, m))
    pos = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None, :],
                           (n_agents, m))
    flat = lambda x: x.reshape(-1)  # noqa: E731
    order = jnp.lexsort((flat(pos), flat(agent), flat(arr)))
    return RequestTrace(arrival=flat(arr)[order],
                        key=flat(key)[order].astype(jnp.int32),
                        kind=flat(kind)[order].astype(jnp.int32),
                        agent=flat(agent)[order].astype(jnp.int32))


def save(path: str, tr: RequestTrace, *, cfg: S.TrafficConfig,
         n_agents: int, n_keys: int, seed: int) -> None:
    """One .npz: the four columns + a JSON provenance record."""
    meta = {"config": dataclasses.asdict(cfg), "n_agents": int(n_agents),
            "n_keys": int(n_keys), "seed": int(seed)}
    np.savez(path,
             arrival=np.asarray(tr.arrival, np.float32),
             key=np.asarray(tr.key, np.int32),
             kind=np.asarray(tr.kind, np.int32),
             agent=np.asarray(tr.agent, np.int32),
             meta=np.frombuffer(json.dumps(meta).encode(), np.uint8))


def load(path: str):
    """-> (RequestTrace, meta dict with 'config' rehydrated)."""
    with np.load(path) as z:
        tr = RequestTrace(arrival=jnp.asarray(z["arrival"]),
                          key=jnp.asarray(z["key"]),
                          kind=jnp.asarray(z["kind"]),
                          agent=jnp.asarray(z["agent"]))
        meta = json.loads(bytes(z["meta"]).decode())
    meta["config"] = S.TrafficConfig(**meta["config"])
    return tr, meta
