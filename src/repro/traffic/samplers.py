"""Seeded, jit-able traffic samplers (DESIGN.md §13).

Every sampler is a pure function of an explicit `jax.random` key plus
static shape/config arguments — no hidden state, no host RNG — so any
stream drawn here is bitwise-replayable from (seed, config) and vmaps
cleanly over seeds (the sweep's replica axis).

  * Key popularity is Zipfian: P(rank r) ∝ (r+1)^-s, sampled by exact
    inverse-CDF search against the normalized cumulative weights (no
    rejection loop — fixed work per sample, jit-friendly).
  * Arrivals are a renewal process on exponential gaps with mean
    `gap_mean`, optionally modulated by an on/off burst envelope:
    consecutive runs of `burst_len` requests flip a fair coin between an
    ON phase (gaps divided by `burstiness`) and an OFF phase (gaps
    multiplied by it).  `burstiness=1.0` makes both phases the identity,
    so the envelope degenerates to plain Poisson *with the same draws* —
    one code path, no branch between processes.
  * The read/write mix is a Bernoulli(`write_frac`) per request.

Arrival clocks are cumulative sums of non-negative gaps: sorted and
non-negative by construction (property-tested in tests/test_traffic.py).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Frozen description of one request stream per agent (hashable —
    rides inside workload configs as a jit static argument)."""
    requests_per_agent: int = 24
    zipf_s: float = 1.1         # key-popularity skew exponent
    gap_mean: float = 32.0      # mean inter-arrival gap (cycles)
    burstiness: float = 1.0     # 1.0 = Poisson; B>1 = on/off with rate x/÷B
    burst_len: int = 8          # requests per on/off phase
    write_frac: float = 0.5     # P(local request is a write)
    remote_frac: float = 0.125  # P(request targets the global key space)


def zipf_cdf(n_keys: int, s: float) -> jnp.ndarray:
    """Normalized cumulative Zipf weights over ranks 0..n_keys-1."""
    ranks = jnp.arange(n_keys, dtype=jnp.float32)
    w = (ranks + 1.0) ** jnp.float32(-s)
    c = jnp.cumsum(w)
    return c / c[-1]


def zipf_ranks(key, n: int, n_keys: int, s: float) -> jnp.ndarray:
    """[n] i32 Zipf(s)-distributed ranks in [0, n_keys) via inverse CDF."""
    u = jax.random.uniform(key, (n,), jnp.float32)
    cdf = zipf_cdf(n_keys, s)
    return jnp.clip(jnp.searchsorted(cdf, u, side="right"),
                    0, n_keys - 1).astype(jnp.int32)


def arrival_clocks(key, n: int, cfg: TrafficConfig) -> jnp.ndarray:
    """[n] f32 sorted, non-negative arrival clocks for one agent stream.

    Renewal process with exponential gaps (mean `gap_mean`), modulated by
    the on/off burst envelope; `burstiness=1.0` IS the Poisson process
    (the envelope multiplies every gap by exactly 1.0)."""
    kg, kp = jax.random.split(key)
    gaps = jax.random.exponential(kg, (n,), jnp.float32) \
        * jnp.float32(cfg.gap_mean)
    n_phases = -(-n // cfg.burst_len)   # ceil
    on = jax.random.bernoulli(kp, 0.5, (n_phases,))
    b = jnp.float32(cfg.burstiness)
    envelope = jnp.where(on, 1.0 / b, b)
    phase = jnp.arange(n, dtype=jnp.int32) // cfg.burst_len
    return jnp.cumsum(gaps * envelope[phase])


def request_kinds(key, n: int, write_frac: float) -> jnp.ndarray:
    """[n] i32 request kinds: 0 = read, 1 = write."""
    return jax.random.bernoulli(key, write_frac, (n,)).astype(jnp.int32)


def remote_draws(key, n: int, remote_frac: float) -> jnp.ndarray:
    """[n] bool: which requests target the global (any-owner) key space."""
    return jax.random.bernoulli(key, remote_frac, (n,))
