"""Trace-driven traffic subsystem (DESIGN.md §13).

Three layers, each importable on its own:

  samplers — seeded, jit-able request-stream primitives: Zipfian key
             popularity, Poisson / on-off-burst arrival processes, and
             a Bernoulli read/write mix.  All pure functions of a PRNG
             key and a frozen `TrafficConfig`, so any derived trace is
             bitwise-replayable from (seed, config).
  trace    — a compact columnar `RequestTrace` (arrival_clock / key /
             kind / agent), generated on the fly from a config+seed,
             saved/loaded as .npz, and replayable at millions of
             simulated requests through the vmapped turn path.
  driver   — the adapter from a RequestTrace to the workload harness's
             can_local / can_remote / remote_bound / live machinery
             (per-agent request streams + cursors), so ANY registered
             workload can be traffic-driven instead of self-driven.

`repro.workloads.kv_serving` is the first consumer: an LLM-serving-tier
workload (hot KV-page ownership, Zipf-skewed lookups, bursty arrivals)
built entirely on these layers.
"""
from repro.traffic.samplers import TrafficConfig  # noqa: F401
from repro.traffic.trace import RequestTrace      # noqa: F401
