"""Logical-axis sharding: one naming scheme resolved against whichever mesh
is active (single-pod ('data','model') or multi-pod ('pod','data','model')).

Models annotate activations with `shard(x, 'batch', None, 'tp')` and param
trees get PartitionSpecs from `param_specs` (path-based rules).  Outside a
mesh context everything is a no-op, so the same model code runs in CPU smoke
tests, the 512-device dry-run, and a real cluster unchanged.

Logical axes:
    batch   — data-parallel batch dim: ('data',) or ('pod','data')
    fsdp    — ZeRO-3 parameter/optimizer sharding dim: ('data',)
    tp      — tensor-parallel dim (heads / ffn / vocab): ('model',)
    expert  — expert-parallel dim for MoE banks: ('model',)
    seqs    — sequence sharding for long-context KV caches: ('data',)
"""
from __future__ import annotations

import contextlib
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: dict = {"mesh": None, "rules": None}


def make_rules(mesh: Mesh, *, fsdp_over_pod: bool = False) -> dict:
    has_pod = "pod" in mesh.axis_names
    return {
        "batch": ("pod", "data") if has_pod else ("data",),
        "fsdp": (("pod", "data") if (has_pod and fsdp_over_pod) else ("data",)),
        "tp": ("model",),
        "sp": ("model",),   # sequence parallelism shares the TP axis
        "expert": ("model",),
        "seqs": ("data",),
        None: None,
    }


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    global _ACTIVE
    prev = dict(_ACTIVE)
    _ACTIVE = {"mesh": mesh, "rules": rules or (make_rules(mesh) if mesh else None)}
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _ACTIVE = prev


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE["mesh"]


def resolve(*logical) -> P:
    rules = _ACTIVE["rules"] or {}
    out = []
    for name in logical:
        ax = rules.get(name) if name is not None else None
        if ax is None:
            out.append(None)
        elif len(ax) == 1:
            out.append(ax[0])
        else:
            out.append(tuple(ax))
    return P(*out)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in names:
        n *= sizes[a]
    return n


def sanitize(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec entries whose mesh extent does not divide the dim (e.g.
    vocab 49155 on a 16-way axis, 40 heads on 16-way TP) and truncate to
    the value's rank — models stay mesh-agnostic."""
    out = []
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    for dim, entry in zip(shape, entries):
        n = _axis_size(mesh, entry)
        out.append(entry if (n > 1 and dim % n == 0) or n == 1 else None)
    return P(*out)


def shard(x: jnp.ndarray, *logical) -> jnp.ndarray:
    """Constrain activation sharding (no-op without an active mesh)."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    spec = sanitize(resolve(*logical[:x.ndim]), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# parameter sharding rules (path-based)
# --------------------------------------------------------------------------

# (regex on '/'-joined param path, logical spec per trailing dims).
# Leading stacked-layer dims (from scan-over-layers) are padded with None.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"(emb|tok_emb)$",            ("tp", "fsdp")),       # [V, d] vocab-parallel
    (r"(head|lm_head|mtp_head)$",  ("fsdp", "tp")),       # [d, V]
    (r"patch_proj$",               ("fsdp", "tp")),
    (r"(wq|wkv|wk|wv|in_proj|w_qkv)$", ("fsdp", "tp")),
    (r"(wq_a|wkv_a)$",             ("fsdp", None)),       # MLA down-proj (small)
    (r"(wq_b|wkv_b)$",             (None, "tp")),         # MLA up-proj
    (r"wo$",                       ("tp", "fsdp")),
    (r"(w1|w3|wi)$",               ("fsdp", "tp")),
    (r"(w2|wo_mlp)$",              ("tp", "fsdp")),
    (r"experts_w[13]$",            ("expert", "fsdp", None)),  # [E, d, f]
    (r"experts_w2$",               ("expert", None, "fsdp")),  # [E, f, d]
    (r"router$",                   ("fsdp", None)),
    (r"(xproj|zproj|bcdt_proj|out_proj)$", ("fsdp", "tp")),
    (r"conv_w$",                   (None, None, "tp")),
    (r"(bias|scale|norm\w*|gamma|beta|a_log|dt_bias|d_skip)$", None),
]


def spec_for(path: str, ndim: int) -> P:
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            if logical is None or ndim < len(logical):
                return P()
            # pad leading stacked-layer dims with None
            names = (None,) * (ndim - len(logical)) + logical
            return resolve(*names)
    return P()  # replicate by default (biases, scalars)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out.append((path, leaf))
    return out, treedef


def param_specs(params_like: Any, mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec tree for a (possibly abstract) param tree; specs are
    sanitized against `mesh` (or the active mesh) for divisibility."""
    mesh = mesh or active_mesh()
    flat, treedef = _flatten_with_paths(params_like)
    specs = []
    for path, leaf in flat:
        s = spec_for(path, getattr(leaf, "ndim", 0))
        if mesh is not None:
            s = sanitize(s, getattr(leaf, "shape", ()), mesh)
        specs.append(s)
    return jax.tree_util.tree_unflatten(treedef, specs)


def drop_axes(spec_tree: Any, axes=("data",)) -> Any:
    """Remove the given mesh axes from every PartitionSpec (e.g. serve-mode
    param layout: replicate over 'data', keep TP) — §Perf decode hillclimb."""
    def fix_entry(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a not in axes)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return None if e in axes else e

    def fix(s):
        return P(*(fix_entry(e) for e in s))

    return jax.tree.map(fix, spec_tree, is_leaf=lambda s: isinstance(s, P))


def param_shardings(params_like: Any, mesh: Optional[Mesh] = None) -> Any:
    mesh = mesh or active_mesh()
    specs = param_specs(params_like)
    if mesh is None:
        return specs
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
