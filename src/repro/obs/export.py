"""Host-side trace decode + Chrome-trace/Perfetto export (DESIGN.md §11).

`decode` reorders the ring buffer oldest-first and reports how many
events overflow dropped; `chrome_trace` renders the result in the
Chrome trace-event JSON object format Perfetto loads directly (one
thread track per agent; modeled cycles are mapped 1:1 onto trace
microseconds), with churn/recovery/straggler instants on a scheduler
track; `write_trace` wraps both and stashes the latency summary under
a top-level "srsp" key so `python -m repro.obs.report FILE` can print a
text report from the JSON alone.
"""
from __future__ import annotations

import json

import numpy as np

from repro.obs import metrics, trace as T

SCHED_TID = 10_000   # instants track, clear of any real agent id


def decode(tl) -> dict:
    """Ring buffer -> numpy event columns, oldest-first.

    Returns {"events": {col: np.ndarray}, "count", "dropped"}."""
    head = int(tl.head)
    cap = tl.clock.shape[0]
    count = min(head, cap)
    start = head % cap if head > cap else 0
    order = (np.arange(count) + start) % cap if count else np.arange(0)
    cols = {k: np.asarray(getattr(tl, k))[order]
            for k in ("clock", "agent", "kind", "scope", "addr",
                      "cycles", "outcome")}
    return {"events": cols, "count": count,
            "dropped": max(head - cap, 0)}


def _outcome_name(kind: int, outcome: int) -> str:
    if kind == T.CHURN:
        return T.CHURN_NAMES.get(outcome, str(outcome))
    return T.OUTCOME_NAMES.get(outcome, str(outcome))


SCOPE_NAMES = {0: "loc", 1: "rem", 2: "glob"}


def chrome_trace(dec: dict, *, n_agents: int = None, meta: dict = None,
                 stragglers=()) -> dict:
    """Chrome trace-event object format (Perfetto-loadable)."""
    ev = dec["events"]
    agents = sorted(set(int(a) for a in ev["agent"])) \
        if n_agents is None else list(range(n_agents))
    out = []
    out.append({"name": "process_name", "ph": "M", "pid": 0,
                "args": {"name": "srsp modeled machine"}})
    for a in agents:
        out.append({"name": "thread_name", "ph": "M", "pid": 0, "tid": a,
                    "args": {"name": f"agent {a}"}})
    out.append({"name": "thread_name", "ph": "M", "pid": 0,
                "tid": SCHED_TID, "args": {"name": "scheduler events"}})
    for i in range(dec["count"]):
        kind = int(ev["kind"][i])
        kname = T.KIND_NAMES.get(kind, str(kind))
        oname = _outcome_name(kind, int(ev["outcome"][i]))
        rec = {"pid": 0, "ts": float(ev["clock"][i]),
               "cat": kname,
               "args": {"addr": int(ev["addr"][i]),
                        "scope": SCOPE_NAMES.get(int(ev["scope"][i]), "?"),
                        "outcome": oname}}
        if kind in (T.CHURN, T.RECOVER):
            # zero-duration scheduler instants on their own track
            rec.update({"name": f"{kname}:{oname} agent "
                                f"{int(ev['agent'][i])}",
                        "ph": "i", "s": "p", "tid": SCHED_TID})
        else:
            rec.update({"name": f"{kname}.{oname}", "ph": "X",
                        "tid": int(ev["agent"][i]),
                        "dur": max(float(ev["cycles"][i]), 0.01)})
        out.append(rec)
    for s in stragglers:
        out.append({"name": f"straggler cell {s.get('cell', '?')}",
                    "ph": "i", "s": "g", "pid": 0, "tid": SCHED_TID,
                    "ts": 0.0, "args": dict(s)})
    doc = {"traceEvents": out, "displayTimeUnit": "ns"}
    if meta:
        doc["srsp"] = meta
    return doc


def trace_meta(store, *, label: str = None, stragglers=()) -> dict:
    """Summary block stashed in the exported JSON (report input)."""
    tl = store.trace
    dec = decode(tl)
    ev = dec["events"]
    kinds = {}
    for kind in np.unique(ev["kind"]).tolist() if dec["count"] else []:
        kinds[T.KIND_NAMES.get(int(kind), str(kind))] = \
            int((ev["kind"] == kind).sum())
    per_scope = {}
    oh = np.asarray(tl.op_hist, np.int64)
    for s, sname in SCOPE_NAMES.items():
        pooled = oh[s].sum(axis=0)
        if pooled.sum():
            per_scope[sname] = metrics.summarize(pooled)
    return {"label": label,
            "n_agents": int(store.counters.cycles.shape[0]),
            "events": int(tl.head), "dropped": dec["dropped"],
            "capacity": T.capacity(tl),
            "kinds": kinds,
            "turn_latency": T.summary(store),
            "op_cycles_per_scope": per_scope,
            "stragglers": list(stragglers)}


def write_trace(path: str, store, *, label: str = None,
                stragglers=()) -> dict:
    """Export a traced store to Perfetto-loadable JSON; returns the doc."""
    dec = decode(store.trace)
    doc = chrome_trace(dec,
                       n_agents=int(store.counters.cycles.shape[0]),
                       meta=trace_meta(store, label=label,
                                       stragglers=stragglers),
                       stragglers=stragglers)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def text_report(doc: dict) -> str:
    """Human-readable report from an exported trace JSON doc."""
    meta = doc.get("srsp") or {}
    lines = []
    label = meta.get("label") or "trace"
    lines.append(f"== sRSP trace report: {label} ==")
    lines.append(f"agents={meta.get('n_agents')} "
                 f"events={meta.get('events')} "
                 f"dropped={meta.get('dropped')} "
                 f"(ring capacity {meta.get('capacity')})")
    if meta.get("kinds"):
        kinds = "  ".join(f"{k}={v}" for k, v in
                          sorted(meta["kinds"].items()))
        lines.append(f"event kinds: {kinds}")
    tl = meta.get("turn_latency") or {}
    if tl.get("latency_turns"):
        lines.append(f"turn latency (modeled cycles, upper-edge): "
                     f"p50={tl['latency_p50']} p95={tl['latency_p95']} "
                     f"p99={tl['latency_p99']} over "
                     f"{tl['latency_turns']} turns")
    for sname, s in (meta.get("op_cycles_per_scope") or {}).items():
        lines.append(f"  {sname:4s} ops: n={s['count']} p50={s['p50']} "
                     f"p95={s['p95']} p99={s['p99']}")
    for s in meta.get("stragglers") or []:
        lines.append(f"straggler: {s}")
    n_spans = sum(1 for e in doc.get("traceEvents", [])
                  if e.get("ph") == "X")
    lines.append(f"{n_spans} spans exported — load the JSON in "
                 f"https://ui.perfetto.dev (or chrome://tracing)")
    return "\n".join(lines)
