"""Log-bucketed cycle histograms and bracketing percentiles (DESIGN.md §11).

The jitted engines cannot keep raw per-turn samples (unbounded length
inside a `lax.while_loop`), so latency distributions are accumulated
into fixed log2 buckets:

    bucket 0      covers [0, 1)
    bucket k >= 1 covers [2^(k-1), 2^k)
    bucket B-1    additionally absorbs everything >= 2^(B-2) (clamp)

Bucket placement uses an exact `searchsorted` against integer power-of-
two edges — no float log, so a sample never lands one bucket off its
edge and the percentile *bracketing* guarantee below is exact:

    percentile_bounds(hist, q) returns (lo, hi) such that any standard
    q-quantile of the raw samples (numpy's linear interpolation between
    order statistics included) satisfies lo <= quantile < hi,

because the interpolated quantile lies between the floor/ceil order
statistics, each of which lies inside its bucket's half-open range.
`summarize` reports the conservative UPPER edge as p50/p95/p99 — a
modeled-latency bound, never an underestimate (property-tested in
tests/test_obs.py).
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

N_BUCKETS = 24

# power-of-two upper edges 1, 2, 4, ..., 2^(B-2); exact in i32/f32
_EDGES = np.asarray([1 << k for k in range(N_BUCKETS - 1)], np.float32)
_EDGES_J = jnp.asarray(_EDGES)


def bucket_index(x) -> jnp.ndarray:
    """Bucket of each non-negative f32 sample (traced; exact edges)."""
    x = jnp.asarray(x, jnp.float32)
    return jnp.searchsorted(_EDGES_J, x, side="right").astype(jnp.int32)


def bucket_lo(k: int) -> float:
    return 0.0 if k == 0 else float(2 ** (k - 1))


def bucket_hi(k: int) -> float:
    return math.inf if k >= N_BUCKETS - 1 else float(2 ** k)


def percentile_bounds(hist, q: float) -> tuple:
    """(lo, hi) edges bracketing the q-quantile of the bucketed samples.

    Host-side.  `hist` is a [N_BUCKETS] count vector; q in [0, 1].
    Empty histogram -> (0.0, 0.0)."""
    h = np.asarray(hist, np.int64)
    c = np.cumsum(h)
    total = int(c[-1]) if h.size else 0
    if total == 0:
        return (0.0, 0.0)
    lo_rank = int(np.floor(q * (total - 1)))   # 0-indexed order statistics
    hi_rank = int(np.ceil(q * (total - 1)))
    klo = int(np.searchsorted(c, lo_rank + 1))
    khi = int(np.searchsorted(c, hi_rank + 1))
    return (bucket_lo(klo), bucket_hi(khi))


def percentile_upper(hist, q: float) -> float:
    """Conservative q-quantile: the bracketing bucket's upper edge.

    The clamp bucket's edge is infinite; report its (finite) lower edge
    instead so JSON stays loadable — the value is then a lower bound and
    the clamp is visible in the histogram itself."""
    lo, hi = percentile_bounds(hist, q)
    return lo if math.isinf(hi) else hi


def summarize(hist) -> dict:
    """{'count', 'p50', 'p95', 'p99'} of a bucketed sample set."""
    h = np.asarray(hist, np.int64)
    return {
        "count": int(h.sum()),
        "p50": percentile_upper(h, 0.50),
        "p95": percentile_upper(h, 0.95),
        "p99": percentile_upper(h, 0.99),
    }
