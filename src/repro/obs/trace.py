"""In-engine event tracing: a fixed-capacity ring buffer carried in the
protocol `Store` (DESIGN.md §11).

`TraceLog` is a pytree of parallel [cap] event columns plus per-scope /
per-agent cycle histograms and a per-agent turn-latency histogram, all
updated with masked scatters inside the jitted schedulers:

* every scoped-ISA op (`repro.core.ops`) appends one event per active
  lane — clock (the lane's cycle counter when the op issued), agent,
  op kind, scope, address, cycles charged to that lane, and a protocol
  outcome (hit / promote / probe / NACK / …) classified from the
  pre-dispatch table state;
* the elastic engines append churn (leave/crash/join) and recovery
  events; the engines bucket each agent's per-turn charged cycles.

Ring overflow policy: `head` is a monotonic event count and an event's
slot is `(position % cap)`, so the buffer always holds the NEWEST `cap`
events; the oldest are overwritten and `dropped = max(head - cap, 0)`
is reported by the decoder — overflow loses history, never corrupts.

Enablement is carried by SHAPE, not by a runtime flag: a disabled log
has zero-capacity columns and every record_* helper returns its input
unchanged via a trace-time Python conditional — the disabled path is
*provably* absent from the compiled program, so every bitwise
equivalence suite holds trivially with tracing off.  `REPRO_TRACE=1`
(read once at import, mirroring REPRO_NO_PACK) makes `make_store`
allocate `REPRO_TRACE_CAP` (default 4096) slots; `with_trace` enables
tracing on an existing state in-process (tests, the report demo).
"""
from __future__ import annotations

import os
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import costmodel
from repro.obs import metrics

TRACE = os.environ.get("REPRO_TRACE", "0") == "1"
DEFAULT_CAP = int(os.environ.get("REPRO_TRACE_CAP", "4096"))

# event kinds
ACQUIRE, RELEASE, LOAD, STORE, CHURN, RECOVER = range(6)
KIND_NAMES = {ACQUIRE: "acquire", RELEASE: "release", LOAD: "load",
              STORE: "store", CHURN: "churn", RECOVER: "recover"}

# op outcomes (CHURN events carry the harness LEAVE/CRASH/JOIN code
# in the outcome column instead — decode dispatches on kind)
OC_NONE, OC_HIT, OC_PROMOTE, OC_PROBE, OC_NACK, OC_GLOBAL, OC_MISS, \
    OC_RECOVER = range(8)
OUTCOME_NAMES = {OC_NONE: "none", OC_HIT: "hit", OC_PROMOTE: "promote",
                 OC_PROBE: "probe", OC_NACK: "nack", OC_GLOBAL: "global",
                 OC_MISS: "miss", OC_RECOVER: "recover"}
CHURN_NAMES = {0: "leave", 1: "crash", 2: "join"}   # harness.KIND_CODES


class TraceLog(NamedTuple):
    """Ring-buffer event log + latency histograms (all leaves jit-carried).

    cap == 0 (the `clock` extent) IS the disabled state; the histogram
    agent axis collapses to 0 with it so a disabled log is empty."""
    head: jnp.ndarray       # [] i32 monotonic event count
    clock: jnp.ndarray      # [cap] f32 issuing lane's cycles at issue
    agent: jnp.ndarray      # [cap] i32
    kind: jnp.ndarray       # [cap] i32 ACQUIRE..RECOVER
    scope: jnp.ndarray      # [cap] i32 ops.LOCAL/REMOTE/GLOBAL
    addr: jnp.ndarray       # [cap] i32 (-1: no address)
    cycles: jnp.ndarray     # [cap] f32 charged to the lane by the op
    outcome: jnp.ndarray    # [cap] i32 OC_* (or churn code for CHURN)
    op_hist: jnp.ndarray    # [3, n, B] i32 per-scope/agent charged cycles
    turn_hist: jnp.ndarray  # [n, B] i32 per-agent per-turn latency


def make(cap: int, n_agents: int) -> TraceLog:
    b = metrics.N_BUCKETS
    m = n_agents if cap else 0
    return TraceLog(
        head=jnp.zeros((), jnp.int32),
        clock=jnp.zeros((cap,), jnp.float32),
        agent=jnp.full((cap,), -1, jnp.int32),
        kind=jnp.full((cap,), -1, jnp.int32),
        scope=jnp.zeros((cap,), jnp.int32),
        addr=jnp.full((cap,), -1, jnp.int32),
        cycles=jnp.zeros((cap,), jnp.float32),
        outcome=jnp.zeros((cap,), jnp.int32),
        op_hist=jnp.zeros((3, m, b), jnp.int32),
        turn_hist=jnp.zeros((m, b), jnp.int32),
    )


def default_cap() -> int:
    return DEFAULT_CAP if TRACE else 0


def enabled(tl: TraceLog) -> bool:
    """Static (shape-level) enablement — safe to branch on in Python."""
    return tl.clock.shape[0] > 0


def capacity(tl: TraceLog) -> int:
    return tl.clock.shape[0]


def with_trace(state, cap: int = None):
    """Enable (or resize) tracing on a Store / workload / elastic state."""
    cap = DEFAULT_CAP if cap is None else cap
    if hasattr(state, "trace") and hasattr(state, "counters"):  # Store
        n = state.counters.cycles.shape[0]
        return state._replace(trace=make(cap, n))
    if hasattr(state, "store"):
        return state._replace(store=with_trace(state.store, cap))
    return state._replace(s=with_trace(state.s, cap))   # ElasticState


def strip(state):
    """Replace the trace with the disabled log — for bitwise comparisons
    across paths whose event ORDER legitimately differs (serial vs
    batched issue the same ops at the same costs in different calls)."""
    return with_trace(state, 0)


# --------------------------------------------------------------------------
# jit-side recording (every helper is a Python-level identity when disabled)
# --------------------------------------------------------------------------

def _append(tl: TraceLog, active, *, clock, agent, kind, scope, addr,
            cycles, outcome) -> TraceLog:
    """Masked ring append: one event per active lane, lane order."""
    cap = tl.clock.shape[0]
    n = active.shape[0]
    active = jnp.asarray(active, bool)
    rank = jnp.cumsum(active.astype(jnp.int32)) - 1
    cnt = jnp.sum(active.astype(jnp.int32))
    # inactive lanes target index `cap`, dropped by the scatter mode
    idx = jnp.where(active, (tl.head + rank) % cap, cap)

    def put(buf, vals):
        vals = jnp.broadcast_to(jnp.asarray(vals, buf.dtype), (n,))
        return buf.at[idx].set(vals, mode="drop")

    return tl._replace(
        head=tl.head + cnt,
        clock=put(tl.clock, clock), agent=put(tl.agent, agent),
        kind=put(tl.kind, kind), scope=put(tl.scope, scope),
        addr=put(tl.addr, addr), cycles=put(tl.cycles, cycles),
        outcome=put(tl.outcome, outcome))


def record_op(st, active, kind, scope, addrs, clock0, outcome):
    """Append one sync/data-op event per active lane and bucket its
    charged cycles into the per-scope histogram.  `clock0` is the
    per-lane cycle vector captured BEFORE dispatch; the charge is the
    lane's own delta across the op.  Identity when tracing is off."""
    tl = st.trace
    if not enabled(tl):
        return st
    n = st.counters.cycles.shape[0]
    active = jnp.asarray(active, bool)
    delta = costmodel.charged_since(st.counters, clock0)
    scope_arr = jnp.clip(jnp.broadcast_to(
        jnp.asarray(scope, jnp.int32), (n,)), 0, 2)
    tl = _append(tl, active, clock=clock0,
                 agent=jnp.arange(n, dtype=jnp.int32), kind=kind,
                 scope=scope_arr, addr=addrs, cycles=delta, outcome=outcome)
    lanes = jnp.arange(n, dtype=jnp.int32)
    tl = tl._replace(op_hist=tl.op_hist.at[
        scope_arr, lanes, metrics.bucket_index(delta)]
        .add(active.astype(jnp.int32)))
    return st._replace(trace=tl)


def record_event(st, mask, kind, outcome, *, addr=None, clock=None,
                 cycles=0.0):
    """Append a scheduler event (churn, recovery) per masked lane.
    Identity when tracing is off."""
    tl = st.trace
    if not enabled(tl):
        return st
    n = st.counters.cycles.shape[0]
    tl = _append(tl, jnp.asarray(mask, bool),
                 clock=st.counters.cycles if clock is None else clock,
                 agent=jnp.arange(n, dtype=jnp.int32), kind=kind,
                 scope=0, addr=-1 if addr is None else addr,
                 cycles=cycles, outcome=outcome)
    return st._replace(trace=tl)


def record_turn(st, clock0):
    """Bucket each agent's charged cycles for one scheduler turn/trip
    (lanes whose clock didn't move didn't act).  Identity when off."""
    tl = st.trace
    if not enabled(tl):
        return st
    n = st.counters.cycles.shape[0]
    delta = costmodel.charged_since(st.counters, clock0)
    acted = delta > 0
    lanes = jnp.arange(n, dtype=jnp.int32)
    return st._replace(trace=tl._replace(
        turn_hist=tl.turn_hist.at[lanes, metrics.bucket_index(delta)]
        .add(acted.astype(jnp.int32))))


# --------------------------------------------------------------------------
# host-side summaries (sweep columns)
# --------------------------------------------------------------------------

def dropped(tl: TraceLog) -> int:
    return max(int(tl.head) - capacity(tl), 0)


def summary(store) -> dict:
    """Schema-v6 latency columns for one run's final store: conservative
    upper-edge percentiles of the pooled per-turn latency histogram,
    plus ring occupancy.  All-None/zero when tracing is off."""
    tl = store.trace
    if not enabled(tl):
        return {"latency_p50": None, "latency_p95": None,
                "latency_p99": None, "latency_turns": 0,
                "trace_events": 0, "trace_dropped": 0}
    pooled = np.asarray(tl.turn_hist, np.int64).sum(axis=0)
    s = metrics.summarize(pooled)
    return {"latency_p50": s["p50"], "latency_p95": s["p95"],
            "latency_p99": s["p99"], "latency_turns": s["count"],
            "trace_events": int(tl.head), "trace_dropped": dropped(tl)}
