"""Observability subsystem (DESIGN.md §11): in-engine event tracing,
log-bucketed latency histograms, and host-side trace export.

* `obs.trace`   — the `TraceLog` ring-buffer pytree carried inside the
  protocol `Store` and appended at the scoped-ISA dispatch choke point;
  a static identity when disabled (the default).
* `obs.metrics` — log2 bucket math and bracketing percentiles.
* `obs.export`  — decode a ring buffer into Chrome-trace/Perfetto JSON
  and a text report.
* `obs.report`  — `python -m repro.obs.report` CLI (plus `--demo`).
"""
