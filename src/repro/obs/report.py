"""Trace report CLI (DESIGN.md §11).

    python -m repro.obs.report TRACE.json          # report an exported trace
    python -m repro.obs.report --demo              # run + trace a demo cell

The demo runs the pinned churned worksteal cell (die-holding-lock crash,
lease-expiry recovery — the richest event mix: local/remote sync ops,
probes, a CHURN instant and a RECOVER drain) with tracing force-enabled
in-process via `trace.with_trace`, exports Perfetto-loadable JSON, and
prints the text report.  `make trace` drives exactly this.
"""
from __future__ import annotations

import argparse
import json
import sys


def _run_demo(args):
    import jax
    import numpy as np

    from repro import workloads
    from repro.core import protocol as P
    from repro.obs import export, trace as T
    from repro.workloads import faults, harness

    victim, at, evt = 0, 5.0, 400.0   # tests/test_churn.py's pinned geometry
    mod = workloads.get(args.workload)
    proto = None
    events = []
    kw = {}
    if args.workload == "worksteal":
        proto = faults.crash_holding_lock(
            P.get_protocol(args.scenario), victim, at)
        events = [(evt, victim, "crash")]
        kw["n_chunks_max"] = 12
    bench = mod.build(args.scenario, args.n_agents, seed=3, proto=proto,
                      **kw)
    eb = harness.make_elastic(bench, events=events)
    state = T.with_trace(eb.state, args.cap)
    with jax.profiler.TraceAnnotation(
            f"demo:{args.workload}/{args.scenario}/n={args.n_agents}"):
        fin = harness.run_batched_elastic(eb.wl, state, *eb.ops)
        jax.block_until_ready(fin.s.store.counters.cycles)
    res = eb.check(fin)
    label = (f"{args.workload}/{args.scenario}/n={args.n_agents}/"
             f"batched_elastic"
             + ("+crash" if events else ""))
    doc = export.write_trace(args.out, fin.s.store, label=label)
    rec = float(np.sum(np.asarray(fin.s.store.counters.recoveries)))
    print(export.text_report(doc))
    print(f"check_ok={res['ok']} recovered={rec:.0f}")
    print(f"wrote {args.out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", nargs="?", help="exported trace JSON to report")
    ap.add_argument("--demo", action="store_true",
                    help="run + trace the demo cell, then report it")
    ap.add_argument("--workload", default="worksteal")
    ap.add_argument("--scenario", default="srsp")
    ap.add_argument("-n", "--n-agents", type=int, default=4)
    ap.add_argument("--cap", type=int, default=None,
                    help="ring capacity for --demo (default REPRO_TRACE_CAP)")
    ap.add_argument("--out", default="TRACE_demo.json",
                    help="output JSON for --demo")
    args = ap.parse_args(argv)
    if args.demo:
        return _run_demo(args)
    if not args.trace:
        ap.error("need a trace JSON path or --demo")
    from repro.obs import export
    with open(args.trace) as f:
        doc = json.load(f)
    print(export.text_report(doc))
    return 0


if __name__ == "__main__":
    sys.exit(main())
