"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block applied
every `attn_every` layers (arXiv:2411.15242).

The shared block's parameters are a single set reused at each application;
each application keeps its own KV cache.  For long_500k decode the KV caches
of the few shared-attention applications are the only sequence-length state
(sharded over 'seqs'); the Mamba2 state is O(1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import ssm
from repro.models.transformer import DTYPES, xent_loss, _head
from repro.sharding import shard


def _dtype(cfg):
    return DTYPES[cfg.dtype]


def _segments(cfg: ModelConfig):
    """Split n_layers into segments; shared attention after each full one."""
    k = cfg.attn_every or cfg.n_layers
    bounds, i = [], 0
    while i < cfg.n_layers:
        j = min(i + k, cfg.n_layers)
        bounds.append((i, j, j - i == k and j < cfg.n_layers + 1))
        i = j
    return bounds  # (start, end, apply_attn_after)


def n_attn_applications(cfg: ModelConfig) -> int:
    return sum(1 for (_, _, a) in _segments(cfg) if a)


def _mamba_layer_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"norm": jnp.ones((cfg.d_model,), jnp.float32),
            "mamba": ssm.mamba2_init(k1, cfg.d_model, cfg.ssm, _dtype(cfg))}


def zamba_init(key, cfg: ModelConfig):
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 6)
    keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: _mamba_layer_init(k, cfg))(keys)
    k1, k2 = jax.random.split(ks[1])
    shared = {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
              "attn": A.gqa_init(k1, cfg, dtype),
              "norm2": jnp.ones((cfg.d_model,), jnp.float32),
              "ffn": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)}
    return {"emb": L.embed_init(ks[2], cfg.vocab, cfg.d_model, dtype),
            "layers": layers, "shared": shared,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "head": L.dense_init(ks[3], cfg.d_model, cfg.vocab, dtype)}


def _slice_stack(stack, a, b):
    return jax.tree.map(lambda x: x[a:b], stack)


def _shared_block(p, cfg, h, positions, *, return_cache=False, block_k=512):
    hn = L.rmsnorm(h, p["norm1"])
    a, kv = A.gqa_train(p["attn"], cfg, hn, positions,
                        return_cache=return_cache, block_k=block_k)
    h = h + a
    hn = L.rmsnorm(h, p["norm2"])
    h = h + L.swiglu_apply(p["ffn"], hn)
    return shard(h, "batch", None, None), kv


def zamba_forward(params, cfg: ModelConfig, tokens, *, remat=True,
                  collect_caches=False, block_k=512):
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = params["emb"][tokens].astype(_dtype(cfg))
    h = shard(h, "batch", None, None)

    def mamba_body(hh, lp):
        hn = L.rmsnorm(hh, lp["norm"])
        y, _ = ssm.mamba2_apply(lp["mamba"], cfg.ssm, cfg.d_model, hn)
        return shard(hh + y, "batch", None, None), None

    if remat:
        mamba_body = jax.checkpoint(
            mamba_body, policy=jax.checkpoint_policies.nothing_saveable)
    kv_caches = []
    for (a, bnd, apply_attn) in _segments(cfg):
        h, _ = lax.scan(mamba_body, h, _slice_stack(params["layers"], a, bnd))
        if apply_attn:
            h, kv = _shared_block(params["shared"], cfg, h, positions,
                                  return_cache=collect_caches,
                                  block_k=block_k)
            if collect_caches:
                kv_caches.append(kv)
    h = L.rmsnorm(h, params["final_norm"])
    return h, kv_caches


def zamba_loss(params, cfg: ModelConfig, batch, *, remat=True, block_k=512):
    h, _ = zamba_forward(params, cfg, batch["tokens"], remat=remat,
                         block_k=block_k)
    logits = _head(params, cfg, h)
    loss = xent_loss(logits, batch["labels"])
    return loss, {"loss": loss, "xent": loss, "aux": 0.0}


# ------------------------------------------------------------------ serving


def zamba_init_cache(cfg: ModelConfig, b: int, max_len: int):
    dt = _dtype(cfg)
    s = cfg.ssm
    di = s.expand * cfg.d_model
    h = di // s.head_dim
    n_l = cfg.n_layers
    mamba = {"conv": jnp.zeros((n_l, b, s.d_conv - 1, di + 2 * s.n_groups
                                * s.d_state), dt),
             "ssm": jnp.zeros((n_l, b, h, s.head_dim, s.d_state), jnp.float32)}
    napp = n_attn_applications(cfg)
    kv = (jnp.zeros((napp, b, cfg.n_kv_heads, max_len, cfg.hd), dt),
          jnp.zeros((napp, b, cfg.n_kv_heads, max_len, cfg.hd), dt))
    return {"mamba": mamba, "attn_kv": kv}


def zamba_prefill(params, cfg: ModelConfig, batch, *, block_k=512):
    """Prefill: run full-seq forward, collecting mamba states and attn KV."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = params["emb"][tokens].astype(_dtype(cfg))

    def mamba_body(hh, lp):
        hn = L.rmsnorm(hh, lp["norm"])
        y, st = ssm.mamba2_apply(lp["mamba"], cfg.ssm, cfg.d_model, hn)
        return hh + y, st

    kv_list, conv_list, ssm_list = [], [], []
    for (a, bnd, apply_attn) in _segments(cfg):
        h, sts = lax.scan(mamba_body, h, _slice_stack(params["layers"], a, bnd))
        conv_list.append(sts["conv"])
        ssm_list.append(sts["ssm"])
        if apply_attn:
            h, kv = _shared_block(params["shared"], cfg, h, positions,
                                  return_cache=True, block_k=block_k)
            kv_list.append(kv)
    h = L.rmsnorm(h, params["final_norm"])
    cache = {"mamba": {"conv": jnp.concatenate(conv_list, 0),
                       "ssm": jnp.concatenate(ssm_list, 0)},
             "attn_kv": (jnp.stack([k for k, _ in kv_list], 0),
                         jnp.stack([v for _, v in kv_list], 0))}
    return _head(params, cfg, h[:, -1]), cache


def zamba_decode_step(params, cfg: ModelConfig, cache, tokens, kv_len,
                      *, block_k=2048):
    b = tokens.shape[0]
    h = params["emb"][tokens].astype(_dtype(cfg))
    mamba, (kstack, vstack) = cache["mamba"], cache["attn_kv"]

    def mamba_step(hh, xs):
        lp, conv, ssm_st = xs
        hn = L.rmsnorm(hh, lp["norm"])
        y, st = ssm.mamba2_decode(lp["mamba"], cfg.ssm, cfg.d_model, hn,
                                  {"conv": conv, "ssm": ssm_st})
        return hh + y, st

    new_conv, new_ssm, new_k, new_v = [], [], [], []
    app = 0
    for (a, bnd, apply_attn) in _segments(cfg):
        h, sts = lax.scan(
            mamba_step, h,
            (_slice_stack(params["layers"], a, bnd),
             mamba["conv"][a:bnd], mamba["ssm"][a:bnd]))
        new_conv.append(sts["conv"])
        new_ssm.append(sts["ssm"])
        if apply_attn:
            p = params["shared"]
            hn = L.rmsnorm(h, p["norm1"])
            att, (nk, nv) = A.gqa_decode(p["attn"], cfg, hn,
                                         (kstack[app], vstack[app]), kv_len,
                                         block_k=block_k)
            h = h + att
            hn = L.rmsnorm(h, p["norm2"])
            h = h + L.swiglu_apply(p["ffn"], hn)
            new_k.append(nk)
            new_v.append(nv)
            app += 1
    h = L.rmsnorm(h, params["final_norm"])
    cache = {"mamba": {"conv": jnp.concatenate(new_conv, 0),
                       "ssm": jnp.concatenate(new_ssm, 0)},
             "attn_kv": (jnp.stack(new_k, 0), jnp.stack(new_v, 0))}
    return _head(params, cfg, h[:, -1]), cache
