"""Attention modules: GQA (with optional QKV bias / partial rotary) and
DeepSeek-style MLA (multi-head latent attention) with the absorbed decode
path over a compressed latent KV cache.

Each module exposes init / train (full-sequence causal) / decode
(single token against a cache) and returns cache updates for prefill.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import shard


# ----------------------------------------------------------------- GQA


def gqa_init(key, cfg: ModelConfig, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, h * hd, dtype),
        "wk": L.dense_init(ks[1], d, hkv * hd, dtype),
        "wv": L.dense_init(ks[2], d, hkv * hd, dtype),
        "wo": L.dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bias_q"] = jnp.zeros((h * hd,), dtype)
        p["bias_k"] = jnp.zeros((hkv * hd,), dtype)
        p["bias_v"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bias_q"], k + p["bias_k"], v + p["bias_v"]
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    rd = int(cfg.partial_rotary * hd)
    q = L.apply_rope(q, positions[:, None, :], cfg.rope_theta, rotary_dim=rd)
    k = L.apply_rope(k, positions[:, None, :], cfg.rope_theta, rotary_dim=rd)
    q = shard(q, "batch", "tp", None, None)
    k = shard(k, "batch", "tp", None, None)
    v = shard(v, "batch", "tp", None, None)
    return q, k, v


def gqa_train(p, cfg: ModelConfig, x, positions, *, causal=True,
              return_cache=False, block_k: int = 512):
    """x [B,S,d]; positions [B,S].  Returns (out [B,S,d], cache|None)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    o = L.blockwise_attention(q, k, v, causal=causal, block_k=block_k)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    out = o @ p["wo"]
    return (out, (k, v)) if return_cache else (out, None)


def gqa_cross(p, cfg: ModelConfig, x, kv_cache, *, block_k: int = 512):
    """Cross attention: q from x, fixed (k, v) from the encoder."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"])
    if cfg.qkv_bias:
        q = q + p["bias_q"]
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k, v = kv_cache
    o = L.blockwise_attention(q, k, v, causal=False, block_k=block_k)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return o @ p["wo"]


def gqa_encode_kv(p, cfg: ModelConfig, enc_out):
    """Precompute cross-attention K/V from encoder output."""
    b, s, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bias_k"], v + p["bias_v"]
    k = k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    return k, v


def gqa_decode(p, cfg: ModelConfig, x, cache: Tuple, kv_len,
               *, block_k: int = 2048):
    """x [B,1,d]; cache (k,v) [B,Hkv,S,D]; kv_len [B] — token goes to slot
    kv_len.  Returns (out [B,1,d], new_cache)."""
    b = x.shape[0]
    pos = kv_len[:, None]  # [B,1]
    q, k_new, v_new = _qkv(p, cfg, x, pos)
    k, v = cache
    bidx = jnp.arange(b)
    k = k.at[bidx, :, kv_len].set(k_new[:, :, 0].astype(k.dtype))
    v = v.at[bidx, :, kv_len].set(v_new[:, :, 0].astype(v.dtype))
    o = L.decode_attention(q[:, :, 0], k, v, kv_len + 1, block_k=block_k)
    out = o.reshape(b, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return out, (k, v)


# ----------------------------------------------------------------- MLA


def mla_init(key, cfg: ModelConfig, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq_a": L.dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "wq_b": L.dense_init(ks[1], m.q_lora_rank, h * (dn + dr), dtype),
        "wkv_a": L.dense_init(ks[2], d, m.kv_lora_rank + dr, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
        "wkv_b": L.dense_init(ks[3], m.kv_lora_rank, h * (dn + dv), dtype),
        "wo": L.dense_init(ks[4], h * dv, d, dtype),
    }


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    q = L.rmsnorm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(b, s, h, dn + dr).transpose(0, 2, 1, 3)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = L.apply_rope(qr, positions[:, None, :], cfg.rope_theta)
    return qn, qr


def _mla_latent(p, cfg, x, positions):
    m = cfg.mla
    kv_a = x @ p["wkv_a"]                       # [B,S,r+dr]
    c = L.rmsnorm(kv_a[..., :m.kv_lora_rank], p["kv_norm"])
    kr = kv_a[..., m.kv_lora_rank:]             # [B,S,dr] shared across heads
    kr = L.apply_rope(kr[:, None], positions[:, None, :], cfg.rope_theta)[:, 0]
    return c, kr


def mla_train(p, cfg: ModelConfig, x, positions, *, causal=True,
              return_cache=False, block_k: int = 512):
    """Non-absorbed full-sequence path (training / prefill)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    qn, qr = _mla_q(p, cfg, x, positions)
    c, kr = _mla_latent(p, cfg, x, positions)
    kv = (c @ p["wkv_b"]).reshape(b, s, h, dn + dv).transpose(0, 2, 1, 3)
    kn, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(kr[:, None], (b, h, s, dr))],
                        axis=-1)
    scale = (dn + dr) ** -0.5
    o = L.blockwise_attention(q, k, v, causal=causal, scale=scale,
                              block_k=block_k)
    out = o.transpose(0, 2, 1, 3).reshape(b, s, h * dv) @ p["wo"]
    return (out, (c, kr)) if return_cache else (out, None)


def mla_decode(p, cfg: ModelConfig, x, cache, kv_len, *, block_k: int = 2048):
    """Absorbed decode over the latent cache (c [B,S,r], kr [B,S,dr]).

    score_h(s) = (W_UK_h^T q_nope_h) · c_s + q_rope_h · kr_s
    out_h      = W_UV_h^T (softmax · c)          — O(S·(r+dr)) per head."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    r = m.kv_lora_rank
    pos = kv_len[:, None]
    qn, qr = _mla_q(p, cfg, x, pos)            # [B,h,1,dn], [B,h,1,dr]
    c_new, kr_new = _mla_latent(p, cfg, x, pos)  # [B,1,r], [B,1,dr]
    c_cache, kr_cache = cache
    bidx = jnp.arange(b)
    c_cache = c_cache.at[bidx, kv_len].set(c_new[:, 0].astype(c_cache.dtype))
    kr_cache = kr_cache.at[bidx, kv_len].set(kr_new[:, 0].astype(kr_cache.dtype))

    w_uk = p["wkv_b"][:, :].reshape(r, h, dn + dv)[:, :, :dn]   # [r,h,dn]
    w_uv = p["wkv_b"][:, :].reshape(r, h, dn + dv)[:, :, dn:]   # [r,h,dv]
    q_lat = jnp.einsum("bhd,rhd->bhr", qn[:, :, 0], w_uk)       # absorb
    # treat (q_lat ++ qr) against cache (c ++ kr) as 1-kv-head attention
    q_full = jnp.concatenate([q_lat, qr[:, :, 0]], axis=-1)     # [B,h,r+dr]
    kv_full = jnp.concatenate([c_cache, kr_cache], axis=-1)     # [B,S,r+dr]
    scale = (dn + dr) ** -0.5
    s_len = kv_full.shape[1]
    ctx = L.decode_attention(q_full, kv_full[:, None], c_cache[:, None],
                             kv_len + 1, scale=scale, block_k=block_k)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv)
    out = out.reshape(b, 1, h * dv) @ p["wo"]
    del s_len
    return out, (c_cache, kr_cache)
