"""Decoder-only transformer LM covering the dense / MoE / MLA / VLM families.

Layers are scanned over stacked params (O(1) compile scaling in depth) with
per-layer remat for training.  The same param tree drives three entry
points: loss (train), prefill (build cache + last-token logits), and
decode_step (one token against the cache).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.sharding import shard

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def _dtype(cfg):
    return DTYPES[cfg.dtype]


def stack_init(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ------------------------------------------------------------------ blocks


def block_init(key, cfg: ModelConfig, kind: str):
    """kind: 'dense' or 'moe' (ffn type); attention chosen by cfg.mla."""
    dtype = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    attn = (A.mla_init(k1, cfg, dtype) if cfg.mla is not None
            else A.gqa_init(k1, cfg, dtype))
    if kind == "moe":
        ffn = M.moe_init(k2, cfg, dtype)
    elif cfg.act == "swiglu":
        ffn = L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
    else:
        ffn = L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return {"norm1": jnp.ones((cfg.d_model,), jnp.float32), "attn": attn,
            "norm2": jnp.ones((cfg.d_model,), jnp.float32), "ffn": ffn}


def _ffn_apply(p, cfg: ModelConfig, kind: str, h2d):
    if kind == "moe":
        y, aux, _ = M.moe_apply(p, cfg, h2d)
        return y, aux
    if cfg.act == "swiglu":
        return L.swiglu_apply(p, h2d), 0.0
    return L.gelu_mlp_apply(p, h2d), 0.0


def block_apply(p, cfg: ModelConfig, kind: str, h, positions, *,
                return_cache=False, block_k=512):
    """Full-sequence (train/prefill) block."""
    b, s, d = h.shape
    hn = L.rmsnorm(h, p["norm1"]) if cfg.norm == "rmsnorm" else \
        L.layernorm(h, p["norm1"], jnp.zeros_like(p["norm1"]))
    attn_fn = A.mla_train if cfg.mla is not None else A.gqa_train
    a, cache = attn_fn(p["attn"], cfg, hn, positions,
                       return_cache=return_cache, block_k=block_k)
    h = h + a
    hn = L.rmsnorm(h, p["norm2"]) if cfg.norm == "rmsnorm" else \
        L.layernorm(h, p["norm2"], jnp.zeros_like(p["norm2"]))
    f, aux = _ffn_apply(p["ffn"], cfg, kind, hn.reshape(b * s, d))
    h = h + f.reshape(b, s, d)
    # sequence parallelism: the residual stream lives seq-sharded on the TP
    # axis; GSPMD turns the per-layer all-reduces into reduce-scatter +
    # all-gather pairs (half the bytes) — EXPERIMENTS.md §Perf
    h = shard(h, "batch", "sp" if cfg.seq_parallel else None, None)
    return h, aux, cache


def block_decode(p, cfg: ModelConfig, kind: str, h, cache, kv_len):
    b, s, d = h.shape
    hn = L.rmsnorm(h, p["norm1"]) if cfg.norm == "rmsnorm" else \
        L.layernorm(h, p["norm1"], jnp.zeros_like(p["norm1"]))
    if cfg.mla is not None:
        a, cache = A.mla_decode(p["attn"], cfg, hn, cache, kv_len)
    else:
        a, cache = A.gqa_decode(p["attn"], cfg, hn, cache, kv_len)
    h = h + a
    hn = L.rmsnorm(h, p["norm2"]) if cfg.norm == "rmsnorm" else \
        L.layernorm(h, p["norm2"], jnp.zeros_like(p["norm2"]))
    f, _ = _ffn_apply(p["ffn"], cfg, kind, hn.reshape(b * s, d))
    h = h + f.reshape(b, s, d)
    return h, cache


# ------------------------------------------------------------------- model


def lm_init(key, cfg: ModelConfig):
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 8)
    n_dense = cfg.moe.first_k_dense if cfg.moe else 0
    n_main = cfg.n_layers - n_dense
    main_kind = "moe" if cfg.moe else "dense"
    params = {
        "emb": L.embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "layers": stack_init(
            lambda k: block_init(k, cfg, main_kind), ks[1], n_main),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if n_dense:
        params["dense_layers"] = stack_init(
            lambda k: block_init(k, cfg, "dense"), ks[2], n_dense)
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(ks[3], cfg.d_model, cfg.vocab, dtype)
    if cfg.n_patches:
        # stub modality frontend: project precomputed patch embeddings
        params["patch_proj"] = L.dense_init(ks[4], 1024, cfg.d_model, dtype)
    if cfg.mtp_heads:
        params["mtp_proj"] = L.dense_init(ks[5], 2 * cfg.d_model, cfg.d_model,
                                          dtype)
        params["mtp_block"] = block_init(ks[6], cfg, "dense")
        params["mtp_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    return params


def _embed(params, cfg: ModelConfig, tokens, patch_embeds=None):
    h = params["emb"][tokens].astype(_dtype(cfg))
    if cfg.n_patches and patch_embeds is not None:
        pe = (patch_embeds.astype(_dtype(cfg)) @ params["patch_proj"])
        npatch = pe.shape[1]
        h = jnp.concatenate([pe, h[:, npatch:]], axis=1)
    return shard(h, "batch", None, None)


def _head(params, cfg: ModelConfig, h):
    w = params["emb"].T if cfg.tie_embeddings else params["head"]
    logits = h @ w
    spec = ("batch",) + (None,) * (logits.ndim - 2) + ("tp",)
    return shard(logits, *spec)


REMAT_POLICIES = {
    "full": jax.checkpoint_policies.nothing_saveable,
    # selective: keep matmul outputs, recompute the cheap elementwise ops —
    # removes the recompute pass's collectives (§Perf)
    "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def _run_stack(params_stack, cfg, kind, h, positions, *, remat=True,
               block_k=512):
    def body(carry, lp):
        hh, aux = carry
        hh, a, _ = block_apply(lp, cfg, kind, hh, positions, block_k=block_k)
        return (hh, aux + a), None

    if remat:
        body = jax.checkpoint(body, policy=REMAT_POLICIES[cfg.remat_policy])
    (h, aux), _ = lax.scan(body, (h, 0.0), params_stack)
    return h, aux


def xent_loss(logits, labels, mask=None):
    """Vocab-sharded stable cross entropy; no full-logit gather."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=lf.dtype)
    gold = jnp.sum(lf * onehot, axis=-1)
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(params, cfg: ModelConfig, batch, *, remat=True, block_k=512):
    """batch: tokens [B,S], labels [B,S] (+ patch_embeds).  Returns
    (loss, metrics)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = _embed(params, cfg, tokens, batch.get("patch_embeds"))
    if "dense_layers" in params:
        h, _ = _run_stack(params["dense_layers"], cfg, "dense", h, positions,
                          remat=remat, block_k=block_k)
    kind = "moe" if cfg.moe else "dense"
    h, aux = _run_stack(params["layers"], cfg, kind, h, positions,
                        remat=remat, block_k=block_k)
    h = L.rmsnorm(h, params["final_norm"])
    logits = _head(params, cfg, h)
    loss = xent_loss(logits, batch["labels"])
    metrics = {"xent": loss, "aux": aux}
    if cfg.mtp_heads:
        # DeepSeek-style multi-token prediction (depth 1): predict t+2 from
        # [h_t ; emb(t_{t+1})] through one extra block, shared head.
        emb_next = params["emb"][batch["labels"]].astype(_dtype(cfg))
        h_mtp = jnp.concatenate([L.rmsnorm(h, params["mtp_norm"]), emb_next],
                                axis=-1) @ params["mtp_proj"]
        h_mtp, _, _ = block_apply(params["mtp_block"], cfg, "dense", h_mtp,
                                  positions, block_k=block_k)
        logits2 = _head(params, cfg, L.rmsnorm(h_mtp, params["final_norm"]))
        labels2 = jnp.concatenate([batch["labels"][:, 1:],
                                   batch["labels"][:, -1:]], axis=1)
        mask2 = jnp.concatenate([jnp.ones((b, s - 1)), jnp.zeros((b, 1))], 1)
        mtp_loss = xent_loss(logits2, labels2, mask2)
        loss = loss + 0.3 * mtp_loss
        metrics["mtp"] = mtp_loss
    loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


# ------------------------------------------------------------------ serving


def lm_prefill(params, cfg: ModelConfig, batch, *, block_k=512):
    """Returns (last_logits [B, V], cache pytree)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = _embed(params, cfg, tokens, batch.get("patch_embeds"))

    def body(hh, lp_kind):
        lp, kind = lp_kind
        hh, _, cache = block_apply(lp, cfg, kind, hh, positions,
                                   return_cache=True, block_k=block_k)
        return hh, cache

    caches = []
    if "dense_layers" in params:
        h, cache_d = lax.scan(lambda hh, lp: body(hh, (lp, "dense")),
                              h, params["dense_layers"])
        caches.append(cache_d)
    kind = "moe" if cfg.moe else "dense"
    h, cache_m = lax.scan(lambda hh, lp: body(hh, (lp, kind)),
                          h, params["layers"])
    caches.append(cache_m)
    h = L.rmsnorm(h, params["final_norm"])
    logits = _head(params, cfg, h[:, -1])
    return logits, caches


def _grow_cache(cache, max_len: int, axis: int):
    """Pad prefill caches along the sequence axis to max_len slots."""
    def pad(x):
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, max_len - x.shape[axis])
        return jnp.pad(x, pads)
    return jax.tree.map(pad, cache)


def lm_grow_cache(cfg, caches, max_len):
    axis = 2 if cfg.mla is not None else 3  # (c,kr):[L,B,S,*] vs (k,v):[L,B,H,S,D]
    return [_grow_cache(c, max_len, axis) for c in caches]


def lm_init_cache(cfg: ModelConfig, b: int, max_len: int):
    """Zero decode cache (for dry-run decode cells the cache is an input)."""
    dt = _dtype(cfg)
    n_dense = cfg.moe.first_k_dense if cfg.moe else 0
    n_main = cfg.n_layers - n_dense

    def one(n):
        if cfg.mla is not None:
            m = cfg.mla
            return (jnp.zeros((n, b, max_len, m.kv_lora_rank), dt),
                    jnp.zeros((n, b, max_len, m.qk_rope_head_dim), dt))
        return (jnp.zeros((n, b, cfg.n_kv_heads, max_len, cfg.hd), dt),
                jnp.zeros((n, b, cfg.n_kv_heads, max_len, cfg.hd), dt))

    caches = []
    if n_dense:
        caches.append(one(n_dense))
    caches.append(one(n_main))
    return caches


def lm_decode_step(params, cfg: ModelConfig, caches, tokens, kv_len,
                   *, block_k=2048):
    """tokens [B,1]; kv_len [B]; returns (logits [B,V], new caches)."""
    h = _embed(params, cfg, tokens)

    def body(hh, xs, kind):
        lp, cache = xs
        hh, cache = block_decode(lp, cfg, kind, hh, cache, kv_len)
        return hh, cache

    new_caches = []
    ci = 0
    if "dense_layers" in params:
        h, cache_d = lax.scan(
            functools.partial(body, kind="dense"), h,
            (params["dense_layers"], caches[ci]))
        new_caches.append(cache_d)
        ci += 1
    kind = "moe" if cfg.moe else "dense"
    h, cache_m = lax.scan(functools.partial(body, kind=kind), h,
                          (params["layers"], caches[ci]))
    new_caches.append(cache_m)
    h = L.rmsnorm(h, params["final_norm"])
    logits = _head(params, cfg, h[:, -1])
    return logits, new_caches
