"""State-space / recurrent sequence mixers.

* Mamba2 — chunked SSD (Dao & Gu 2024) for train/prefill, O(1)-state
  recurrence for decode.  Used by zamba2 (hybrid).
* mLSTM  — chunkwise-parallel matrix-memory LSTM with exp-gating and
  m-stabilizer (xLSTM, arXiv:2405.04517); recurrent form for decode.
* sLSTM  — scalar-memory recurrent cell with state mixing (lax.scan).

All are O(1) state at decode time — these are the arch families that run the
long_500k cell (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L

# ============================================================== Mamba2 (SSD)


def _segsum(x):
    """x [..., l] -> [..., l, l]; S[i,j] = sum_{j < k <= i} x[k]; -inf above."""
    l = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    s = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(x, dA, B, C, chunk: int, init_state=None):
    """Chunked SSD scan.

    x  [b, l, h, p]   (pre-multiplied by dt)
    dA [b, l, h]      (dt * A, negative)
    B  [b, l, g, n], C [b, l, g, n]  (g groups; h % g == 0)
    Returns (y [b, l, h, p], final_state [b, h, p, n])."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    B = jnp.repeat(B, rep, axis=2)          # [b,l,h,n]
    C = jnp.repeat(C, rep, axis=2)
    assert l % chunk == 0, (l, chunk)
    nc, cl = l // chunk, chunk

    xr = x.reshape(b, nc, cl, h, p)
    Br = B.reshape(b, nc, cl, h, n)
    Cr = C.reshape(b, nc, cl, h, n)
    Ar = dA.reshape(b, nc, cl, h).transpose(0, 3, 1, 2)      # [b,h,nc,cl]
    A_cum = jnp.cumsum(Ar, axis=-1)

    # intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(Ar))                              # [b,h,nc,cl,cl]
    Y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        Cr, Br, Lmat, xr)

    # per-chunk end states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)          # [b,h,nc,cl]
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Br, decay_states, xr)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(A_cum[..., -1])                    # [b,h,nc]
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st_c, dec_c = inp                                    # [b,h,p,n],[b,h]
        prev = carry
        new = prev * dec_c[..., None, None] + st_c
        return new, prev

    states_t = states.transpose(1, 0, 2, 3, 4)               # [nc,b,h,p,n]
    decay_t = chunk_decay.transpose(2, 0, 1)                 # [nc,b,h]
    final, prev_states = lax.scan(step, init_state.astype(jnp.float32),
                                  (states_t.astype(jnp.float32), decay_t))
    prev_states = prev_states.transpose(1, 2, 0, 3, 4)       # [b,h,nc,p,n]

    state_decay = jnp.exp(A_cum)                             # [b,h,nc,cl]
    Y_off = jnp.einsum("bclhn,bhcpn,bhcl->bclhp", Cr, prev_states, state_decay)
    y = (Y_diag + Y_off).reshape(b, l, h, p)
    return y, final


def mamba2_init(key, d_model: int, ssm, dtype):
    di = ssm.expand * d_model
    h = di // ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state
    proj_out = 2 * di + 2 * g * n + h      # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (h,)) * (math.log(0.1) - math.log(0.001))
                 + math.log(0.001))
    return {
        "in_proj": L.dense_init(ks[0], d_model, proj_out, dtype),
        "conv_w": jax.random.normal(ks[1], (ssm.d_conv, 1, di + 2 * g * n),
                                    dtype) * 0.1,
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_y": jnp.ones((di,), jnp.float32),
        "out_proj": L.dense_init(ks[3], di, d_model, dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv.  x [b,l,c]; w [k,1,c]; state [b,k-1,c]|None.
    Returns (y [b,l,c], new_state [b,k-1,c])."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    new_state = xp[:, -(k - 1):] if k > 1 else state
    y = sum(xp[:, i:i + x.shape[1]] * w[i, 0] for i in range(k))
    return y, new_state


def mamba2_apply(p, ssm, d_model: int, x, *, init=None, chunk=None):
    """Full-sequence Mamba2 mixer.  x [b,l,d] -> (y [b,l,d], state)."""
    b, l, d = x.shape
    di = ssm.expand * d_model
    h = di // ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state
    chunk = chunk or ssm.chunk
    zxbcdt = x @ p["in_proj"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state = None if init is None else init["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # [b,l,h]
    A = -jnp.exp(p["a_log"])                                      # [h]
    xh = xin.reshape(b, l, h, ssm.head_dim).astype(jnp.float32)
    Bh = Bc.reshape(b, l, g, n).astype(jnp.float32)
    Ch = Cc.reshape(b, l, g, n).astype(jnp.float32)
    ssm_state = None if init is None else init["ssm"]
    chunk = min(chunk, l)
    pad = (-l) % chunk  # zero-pad: dA=0 (decay 1) and x=0 leave state intact
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    y, final = ssd_chunked(xh * dt[..., None], dt * A, Bh, Ch, chunk,
                           init_state=ssm_state)
    if pad:
        y = y[:, :l]
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, l, di)
    y = L.rmsnorm(y, p["norm_y"]) * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": final}


def mamba2_decode(p, ssm, d_model: int, x, state):
    """Single-token recurrence.  x [b,1,d]; state {conv, ssm}."""
    b = x.shape[0]
    di = ssm.expand * d_model
    h = di // ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state
    zxbcdt = x @ p["in_proj"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + g * n], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [b,h]
    A = -jnp.exp(p["a_log"])
    xh = xin[:, 0].reshape(b, h, ssm.head_dim).astype(jnp.float32)
    Bh = jnp.repeat(Bc[:, 0].reshape(b, g, n), h // g, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cc[:, 0].reshape(b, g, n), h // g, axis=1).astype(jnp.float32)
    dec = jnp.exp(dt * A)                                          # [b,h]
    hs = state["ssm"] * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", hs, Ch) + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, di)
    y = L.rmsnorm(y, p["norm_y"]) * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["out_proj"]
    return out, {"conv": new_conv, "ssm": hs}


# ============================================================== mLSTM (xLSTM)


class MLSTMState(NamedTuple):
    C: jnp.ndarray  # [b, h, dk, dv]
    n: jnp.ndarray  # [b, h, dk]
    m: jnp.ndarray  # [b, h]


def mlstm_zero_state(b, h, dk, dv):
    return MLSTMState(jnp.zeros((b, h, dk, dv), jnp.float32),
                      jnp.zeros((b, h, dk), jnp.float32),
                      jnp.full((b, h), -1e30, jnp.float32))


def mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk: int,
                    state: MLSTMState | None = None):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v [b,l,h,dh]; i_raw,f_raw [b,l,h].  Returns (h [b,l,h,dh], state)."""
    b, l, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:  # pad gates so padded steps neither decay nor write state
        zp = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, zp) for a in (q, k, v))
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        f_raw = jnp.pad(f_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=40.0)
        l = l + pad
    nc, cl = l // chunk, chunk
    scale = dk ** -0.5
    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))     # [b,l,h]
    logi = i_raw.astype(jnp.float32)
    if state is None:
        state = mlstm_zero_state(b, h, dk, dv)

    qr = (q.astype(jnp.float32) * scale).reshape(b, nc, cl, h, dk)
    kr = k.astype(jnp.float32).reshape(b, nc, cl, h, dk)
    vr = v.astype(jnp.float32).reshape(b, nc, cl, h, dv)
    fr = logf.reshape(b, nc, cl, h).transpose(0, 3, 1, 2)    # [b,h,nc,cl]
    ir = logi.reshape(b, nc, cl, h).transpose(0, 3, 1, 2)
    bcum = jnp.cumsum(fr, axis=-1)                           # [b,h,nc,cl]
    btot = bcum[..., -1]                                     # [b,h,nc]
    tril = jnp.tril(jnp.ones((cl, cl), bool))

    def chunk_step(carry: MLSTMState, inp):
        C_p, n_p, m_p = carry
        qc, kc, vc, bc, ic, btc = inp
        # qc [b,cl,h,dk] ...; bc/ic [b,h,cl]
        # intra-chunk log weights computed HERE (inside remat) so the
        # O(cl^2) decay matrix is a transient, not a saved residual
        ldc = bc[..., :, None] - bc[..., None, :] + ic[..., None, :]
        ldc = jnp.where(tril, ldc, -jnp.inf)                 # [b,h,cl,cl]
        mint = jnp.max(ldc, axis=-1)                         # [b,h,cl]
        m_inter = m_p[..., None] + bc                        # [b,h,cl]
        m_t = jnp.maximum(mint, m_inter)
        m_t = jnp.maximum(m_t, -1e30)
        S = jnp.einsum("bthd,bshd->bhts", qc, kc) * jnp.exp(ldc - m_t[..., None])
        inter_w = jnp.exp(m_inter - m_t)                     # [b,h,cl]
        num = jnp.einsum("bhts,bshd->bthd", S, vc) + \
            jnp.einsum("bthd,bhdv,bht->bthv", qc, C_p, inter_w)
        den = jnp.sum(S, axis=-1) + \
            jnp.einsum("bthd,bhd,bht->bht", qc, n_p, inter_w)  # [b,h,t]
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        hh = num / den.transpose(0, 2, 1)[..., None]         # [b,t,h,dv]
        # state update to end of chunk
        upd_w = btc[..., None] - bc + ic                     # [b,h,cl]
        m_new = jnp.maximum(m_p + btc, jnp.max(upd_w, axis=-1))
        C_new = C_p * jnp.exp(m_p + btc - m_new)[..., None, None] + \
            jnp.einsum("bht,bthd,bthv->bhdv", jnp.exp(upd_w - m_new[..., None]),
                       kc, vc)
        n_new = n_p * jnp.exp(m_p + btc - m_new)[..., None] + \
            jnp.einsum("bht,bthd->bhd", jnp.exp(upd_w - m_new[..., None]), kc)
        return MLSTMState(C_new, n_new, m_new), hh

    chunk_step = jax.checkpoint(
        chunk_step, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (qr.transpose(1, 0, 2, 3, 4), kr.transpose(1, 0, 2, 3, 4),
          vr.transpose(1, 0, 2, 3, 4), bcum.transpose(2, 0, 1, 3),
          ir.transpose(2, 0, 1, 3), btot.transpose(2, 0, 1))
    final, hs = lax.scan(chunk_step, state, xs)
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, l, h, dv)
    if pad:
        hs = hs[:, :l - pad]
    return hs, final


def mlstm_step(q, k, v, i_raw, f_raw, state: MLSTMState):
    """Single-token recurrent mLSTM.  q,k,v [b,h,dh]; i,f [b,h]."""
    dk = q.shape[-1]
    scale = dk ** -0.5
    logf = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))
    logi = i_raw.astype(jnp.float32)
    m_new = jnp.maximum(logf + state.m, logi)
    fw = jnp.exp(logf + state.m - m_new)
    iw = jnp.exp(logi - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = state.C * fw[..., None, None] + iw[..., None, None] * \
        kf[..., :, None] * vf[..., None, :]
    n = state.n * fw[..., None] + iw[..., None] * kf
    qf = q.astype(jnp.float32) * scale
    num = jnp.einsum("bhd,bhdv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)),
                      jnp.exp(-m_new))
    return num / den[..., None], MLSTMState(C, n, m_new)


# ============================================================== sLSTM (xLSTM)


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # [b, h, dh]
    n: jnp.ndarray
    h: jnp.ndarray
    m: jnp.ndarray  # [b, h, dh]


def slstm_zero_state(b, h, dh):
    z = jnp.zeros((b, h, dh), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((b, h, dh), -1e30, jnp.float32))


def slstm_cell(gates_x, r_w, state: SLSTMState):
    """One sLSTM step.  gates_x [b, 4, h, dh] (i,f,z,o pre-activations from
    the input); r_w [4, h, dh, dh] recurrent block-diagonal weights."""
    rec = jnp.einsum("bhd,ghde->bghe", state.h, r_w)       # [b,4,h,dh]
    i_r, f_r, z_r, o_r = [gates_x[:, g] + rec[:, g] for g in range(4)]
    m_new = jnp.maximum(f_r + state.m, i_r)
    iw = jnp.exp(i_r - m_new)
    fw = jnp.exp(f_r + state.m - m_new)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    c = fw * state.c + iw * z
    n = fw * state.n + iw
    hh = o * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, hh, m_new), hh


def slstm_apply(gates_seq, r_w, state: SLSTMState, *, segment: int = 64):
    """gates_seq [b, l, 4, h, dh] -> (h [b, l, h, dh], state).

    BPTT memory control: outer scan saves the carry only at segment
    boundaries; the inner (remat'd) scan recomputes within a segment."""
    b, l = gates_seq.shape[0], gates_seq.shape[1]
    segment = min(segment, l)
    pad = (-l) % segment
    g = gates_seq
    if pad:
        g = jnp.pad(g, ((0, 0), (0, pad)) + ((0, 0),) * (g.ndim - 2))
    nseg = (l + pad) // segment
    g = g.reshape(b, nseg, segment, *g.shape[2:]).transpose(1, 2, 0, 3, 4, 5)

    def inner(carry, gt):
        return slstm_cell(gt, r_w, carry)

    def outer(carry, gseg):
        new, hs = lax.scan(inner, carry, gseg)
        return new, hs

    outer = jax.checkpoint(outer,
                           policy=jax.checkpoint_policies.nothing_saveable)
    final, hs = lax.scan(outer, state, g)   # hs [nseg, seg, b, h, dh]
    hs = hs.reshape(nseg * segment, b, *hs.shape[3:]).transpose(1, 0, 2, 3)
    if pad:
        hs = hs[:, :l]
    return hs, final
