"""Foundational layers — functional (pytree params + pure apply fns).

Attention is implemented *blockwise* (online softmax over KV blocks via
lax.scan) so the lowered HLO keeps O(S·block) live memory rather than
O(S^2); this is what makes the 32k-prefill dry-run cells honest without
requiring the Pallas kernel at trace time (DESIGN.md §6.2).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

Params = dict

# ---------------------------------------------------------------- init utils


def uniform_init(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def dense_init(key, d_in, d_out, dtype=jnp.float32):
    scale = math.sqrt(1.0 / d_in)
    return uniform_init(key, (d_in, d_out), scale, dtype)


def embed_init(key, vocab, d, dtype=jnp.float32):
    return jax.random.normal(key, (vocab, d), dtype) * 0.02


# ------------------------------------------------------------------- norms


def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# -------------------------------------------------------------------- rope


def rope_freqs(head_dim: int, theta: float = 10000.0, rotary_dim: int | None = None):
    rd = rotary_dim or head_dim
    return 1.0 / (theta ** (jnp.arange(0, rd, 2, dtype=jnp.float32) / rd))


def apply_rope(x, positions, theta: float = 10000.0,
               rotary_dim: int | None = None):
    """x [..., S, D] (head dim last); positions [..., S] int32."""
    d = x.shape[-1]
    rd = rotary_dim or d
    inv = rope_freqs(d, theta, rd)
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, rd/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    xr = x[..., :rd].astype(jnp.float32)
    x1, x2 = xr[..., ::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    rotated = jnp.stack([y1, y2], axis=-1).reshape(*xr.shape)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rd:]], axis=-1)


# ---------------------------------------------------------------- attention

NEG_INF = -1e30


def blockwise_attention(q, k, v, *, causal: bool = True, block_k: int = 512,
                        q_offset=0, kv_len=None,
                        scale: float | None = None) -> jnp.ndarray:
    """Online-softmax attention, O(S_q · block_k) live memory.

    q [B, H, Sq, D]; k, v [B, Hkv, Sk, D]; Hq % Hkv == 0.
    `q_offset`: absolute position of q[..,0,:] (for prefill continuation).
    `kv_len` [B]: valid KV prefix (for decode over ring caches)."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    dv = v.shape[-1]  # value head dim may differ (e.g. MLA latent values)
    group = h // hkv
    if scale is None:
        scale = d ** -0.5
    block_k = min(block_k, sk)
    nblk = (sk + block_k - 1) // block_k
    pad = nblk * block_k - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, hkv, nblk, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nblk, block_k, dv).transpose(2, 0, 1, 3, 4)

    qf = q.astype(jnp.float32) * scale
    rows = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m, l, acc = carry
        jblk, kblk, vblk = inp
        kf = kblk.astype(jnp.float32)
        # GQA: expand kv heads to q heads
        kf = jnp.repeat(kf, group, axis=1)
        vf = jnp.repeat(vblk.astype(jnp.float32), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
        cols = jblk * block_k + jnp.arange(block_k)
        mask = jnp.ones((sq, block_k), bool)
        if causal:
            mask = mask & (rows[:, None] >= cols[None, :])
        mask = mask & (cols[None, :] < sk)
        if kv_len is not None:
            s = jnp.where(cols[None, None, None, :] < kv_len[:, None, None, None],
                          s, NEG_INF)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p, vf)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq, 1), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0),
                              (jnp.arange(nblk), kb, vb))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)


def decode_attention(q, k, v, kv_len, *, scale: float | None = None,
                     block_k: int = 2048) -> jnp.ndarray:
    """Single-token decode: q [B, H, D], cache k/v [B, Hkv, S, D], kv_len [B].

    Direct (non-blockwise) form: at q-length 1 the score tensor is only
    O(B·H·S), and the grouped einsum avoids materializing repeated KV heads.
    Under GSPMD this shards cleanly with the cache sequence axis distributed:
    the softmax reductions become tiny psums (distributed flash-decode)."""
    del block_k
    b, h, d = q.shape
    _, hkv, s, _ = k.shape
    dv = v.shape[-1]
    group = h // hkv
    if scale is None:
        scale = d ** -0.5
    # keep the cache operands in their storage dtype and accumulate in f32
    # (preferred_element_type) — upcasting k/v wholesale makes XLA hoist a
    # full-cache f32 copy out of the layer scan (§Perf, decode hillclimb)
    qg = (q.astype(jnp.float32) * scale).astype(k.dtype) \
        .reshape(b, hkv, group, d)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg, k,
                        preferred_element_type=jnp.float32)
    pos = jnp.arange(s)[None, None, None, :]
    logits = jnp.where(pos < kv_len[:, None, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bksd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------- mlp


def swiglu_init(key, d, f, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": dense_init(k1, d, f, dtype), "w3": dense_init(k2, d, f, dtype),
            "w2": dense_init(k3, f, d, dtype)}


def swiglu_apply(p, x):
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]


def gelu_mlp_init(key, d, f, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, d, f, dtype), "wo_mlp": dense_init(k2, f, d, dtype),
            "bias_i": jnp.zeros((f,), dtype), "bias_o": jnp.zeros((d,), dtype)}


def gelu_mlp_apply(p, x):
    h = jax.nn.gelu((x @ p["wi"]) + p["bias_i"])
    return (h @ p["wo_mlp"]) + p["bias_o"]
