"""xLSTM language model: alternating mLSTM / sLSTM blocks (arXiv:2405.04517).

mLSTM blocks use the chunkwise-parallel form for train/prefill and the O(1)
matrix-memory recurrence for decode; sLSTM blocks are strictly sequential
(lax.scan over time).  Constant-size state makes this family long_500k
capable."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models.transformer import DTYPES, xent_loss, _head
from repro.sharding import shard

D_CONV = 4


def _dtype(cfg):
    return DTYPES[cfg.dtype]


# ------------------------------------------------------------- mLSTM block


def mlstm_block_init(key, cfg: ModelConfig):
    dtype = _dtype(cfg)
    d = cfg.d_model
    di = 2 * d                      # projection factor 2 (xLSTM paper)
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "w_up": L.dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": jax.random.normal(ks[1], (D_CONV, 1, di), dtype) * 0.1,
        "wq": L.dense_init(ks[2], di, di, dtype),
        "wk": L.dense_init(ks[3], di, di, dtype),
        "wv": L.dense_init(ks[4], di, di, dtype),
        "w_if": L.dense_init(ks[5], di, 2 * h, dtype),
        "norm_h": jnp.ones((di,), jnp.float32),
        "w_down": L.dense_init(ks[6], di, d, dtype),
    }


def mlstm_block_apply(p, cfg: ModelConfig, x, *, state=None, chunk=1024):
    """x [b,l,d].  Returns (y, {'conv':..., 'mlstm': MLSTMState})."""
    b, l, d = x.shape
    di = 2 * d
    h = cfg.n_heads
    dh = di // h
    xn = L.rmsnorm(x, p["norm"])
    up = xn @ p["w_up"]
    x_in, z = jnp.split(up, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xc, new_conv = ssm._causal_conv(x_in, p["conv_w"], conv_state)
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(b, l, h, dh)
    k = (xc @ p["wk"]).reshape(b, l, h, dh)
    v = (x_in @ p["wv"]).reshape(b, l, h, dh)
    i_f = xc @ p["w_if"]
    i_raw, f_raw = i_f[..., :h], i_f[..., h:]
    prev = None if state is None else state["mlstm"]
    hs, new_state = ssm.mlstm_chunkwise(q, k, v, i_raw, f_raw,
                                        chunk=min(chunk, l), state=prev)
    hs = hs.reshape(b, l, di)
    y = L.rmsnorm(hs, p["norm_h"]) * jax.nn.silu(z.astype(jnp.float32))
    y = y.astype(x.dtype) @ p["w_down"]
    return x + y, {"conv": new_conv, "mlstm": new_state}


def mlstm_block_step(p, cfg: ModelConfig, x, state):
    """x [b,1,d] single-token decode."""
    b, _, d = x.shape
    di = 2 * d
    h = cfg.n_heads
    dh = di // h
    xn = L.rmsnorm(x, p["norm"])
    up = xn @ p["w_up"]
    x_in, z = jnp.split(up, 2, axis=-1)
    xc, new_conv = ssm._causal_conv(x_in, p["conv_w"], state["conv"])
    xc = jax.nn.silu(xc)
    q = (xc @ p["wq"]).reshape(b, h, dh)
    k = (xc @ p["wk"]).reshape(b, h, dh)
    v = (x_in @ p["wv"]).reshape(b, h, dh)
    i_f = (xc @ p["w_if"])[:, 0]
    out, new_state = ssm.mlstm_step(q, k, v, i_f[:, :h], i_f[:, h:],
                                    state["mlstm"])
    hs = out.reshape(b, 1, di)
    y = L.rmsnorm(hs, p["norm_h"]) * jax.nn.silu(z.astype(jnp.float32))
    y = y.astype(x.dtype) @ p["w_down"]
    return x + y, {"conv": new_conv, "mlstm": new_state}


def mlstm_zero(cfg: ModelConfig, b: int):
    di = 2 * cfg.d_model
    h = cfg.n_heads
    dh = di // h
    return {"conv": jnp.zeros((b, D_CONV - 1, di), _dtype(cfg)),
            "mlstm": ssm.mlstm_zero_state(b, h, dh, dh)}


# ------------------------------------------------------------- sLSTM block


def slstm_block_init(key, cfg: ModelConfig):
    dtype = _dtype(cfg)
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    f = int(d * 4 / 3 / 64) * 64 or 64
    ks = jax.random.split(key, 4)
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "w_gates": L.dense_init(ks[0], d, 4 * d, dtype),
        "r_w": jax.random.normal(ks[1], (4, h, dh, dh), jnp.float32)
        * (1.0 / dh) ** 0.5,
        "norm_h": jnp.ones((d,), jnp.float32),
        "w_proj": L.dense_init(ks[2], d, d, dtype),
        "mlp": L.gelu_mlp_init(ks[3], d, f, dtype),
        "norm2": jnp.ones((d,), jnp.float32),
    }


def _slstm_gates(p, cfg, xn):
    b, l, d = xn.shape
    h = cfg.n_heads
    dh = d // h
    g = (xn @ p["w_gates"]).astype(jnp.float32)
    return g.reshape(b, l, 4, h, dh)


def slstm_block_apply(p, cfg: ModelConfig, x, *, state=None):
    b, l, d = x.shape
    xn = L.rmsnorm(x, p["norm"])
    gates = _slstm_gates(p, cfg, xn)
    st = state["slstm"] if state is not None else \
        ssm.slstm_zero_state(b, cfg.n_heads, d // cfg.n_heads)
    hs, new_state = ssm.slstm_apply(gates, p["r_w"], st)
    hs = hs.reshape(b, l, d)
    y = (L.rmsnorm(hs, p["norm_h"]).astype(x.dtype)) @ p["w_proj"]
    x = x + y
    x = x + L.gelu_mlp_apply(p["mlp"], L.rmsnorm(x, p["norm2"]))
    return x, {"slstm": new_state}


def slstm_block_step(p, cfg: ModelConfig, x, state):
    b, _, d = x.shape
    xn = L.rmsnorm(x, p["norm"])
    gates = _slstm_gates(p, cfg, xn)[:, 0]
    new_state, hh = ssm.slstm_cell(gates, p["r_w"], state["slstm"])
    hs = hh.reshape(b, 1, d)
    y = (L.rmsnorm(hs, p["norm_h"]).astype(x.dtype)) @ p["w_proj"]
    x = x + y
    x = x + L.gelu_mlp_apply(p["mlp"], L.rmsnorm(x, p["norm2"]))
    return x, {"slstm": new_state}


def slstm_zero(cfg: ModelConfig, b: int):
    return {"slstm": ssm.slstm_zero_state(b, cfg.n_heads,
                                          cfg.d_model // cfg.n_heads)}


# ------------------------------------------------------------------- model


def _pattern(cfg: ModelConfig) -> str:
    return cfg.xlstm_pattern or "ms" * (cfg.n_layers // 2)


def xlstm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, cfg.n_layers + 3)
    blocks = []
    for i, ch in enumerate(_pattern(cfg)):
        init = mlstm_block_init if ch == "m" else slstm_block_init
        blocks.append(init(ks[i], cfg))
    return {
        "emb": L.embed_init(ks[-3], cfg.vocab, cfg.d_model, _dtype(cfg)),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "head": L.dense_init(ks[-2], cfg.d_model, cfg.vocab, _dtype(cfg)),
    }


def xlstm_forward(params, cfg: ModelConfig, tokens, *, states=None,
                  collect_states=False, chunk=1024, remat=False):
    h = params["emb"][tokens].astype(_dtype(cfg))
    h = shard(h, "batch", None, None)
    new_states = []
    for i, ch in enumerate(_pattern(cfg)):
        st = None if states is None else states[i]
        if ch == "m":
            fn = lambda p, hh, s: mlstm_block_apply(p, cfg, hh, state=s,
                                                    chunk=chunk)
        else:
            fn = lambda p, hh, s: slstm_block_apply(p, cfg, hh, state=s)
        if remat:  # per-block remat: only the block input survives to bwd
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.nothing_saveable,
                static_argnums=())
        h, ns = fn(params["blocks"][i], h, st)
        new_states.append(ns)
    h = L.rmsnorm(h, params["final_norm"])
    return h, (new_states if collect_states or states is not None else None)


def xlstm_loss(params, cfg: ModelConfig, batch, *, remat=True, **_):
    h, _ = xlstm_forward(params, cfg, batch["tokens"], remat=remat)
    logits = _head(params, cfg, h)
    loss = xent_loss(logits, batch["labels"])
    return loss, {"loss": loss, "xent": loss, "aux": 0.0}


def xlstm_prefill(params, cfg: ModelConfig, batch, **_):
    h, states = xlstm_forward(params, cfg, batch["tokens"],
                              collect_states=True)
    return _head(params, cfg, h[:, -1]), states


def xlstm_init_cache(cfg: ModelConfig, b: int, max_len: int):
    del max_len  # constant-size state
    return [mlstm_zero(cfg, b) if ch == "m" else slstm_zero(cfg, b)
            for ch in _pattern(cfg)]


def xlstm_decode_step(params, cfg: ModelConfig, states, tokens, kv_len, **_):
    del kv_len  # recurrent state carries position implicitly
    h = params["emb"][tokens].astype(_dtype(cfg))
    new_states = []
    for i, ch in enumerate(_pattern(cfg)):
        step = mlstm_block_step if ch == "m" else slstm_block_step
        h, ns = step(params["blocks"][i], cfg, h, states[i])
        new_states.append(ns)
    h = L.rmsnorm(h, params["final_norm"])
    return _head(params, cfg, h[:, -1]), new_states
