"""Encoder-decoder transformer (seamless-m4t-large-v2 backbone).

The audio/text modality frontend is a STUB per the assignment brief:
``input_specs()`` supplies precomputed frame embeddings [B, S_enc, 1024]
which a learned frame_proj maps into the model.  Encoder is bidirectional;
decoder has causal self-attention + cross-attention.  For decode shapes the
encoder length is seq_len // 8 (documented in DESIGN.md §4)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models.transformer import DTYPES, stack_init, xent_loss, _head
from repro.sharding import shard

FRONTEND_DIM = 1024


def _dtype(cfg):
    return DTYPES[cfg.dtype]


def _enc_block_init(key, cfg):
    dtype = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": A.gqa_init(k1, cfg, dtype),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
            "ffn": L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def _dec_block_init(key, cfg):
    dtype = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "self_attn": A.gqa_init(k1, cfg, dtype),
            "norm_x": jnp.ones((cfg.d_model,), jnp.float32),
            "cross_attn": A.gqa_init(k2, cfg, dtype),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
            "ffn": L.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype)}


def encdec_init(key, cfg: ModelConfig):
    dtype = _dtype(cfg)
    ks = jax.random.split(key, 6)
    return {
        "frame_proj": L.dense_init(ks[0], FRONTEND_DIM, cfg.d_model, dtype),
        "enc_layers": stack_init(lambda k: _enc_block_init(k, cfg), ks[1],
                                 cfg.enc_layers),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "emb": L.embed_init(ks[2], cfg.vocab, cfg.d_model, dtype),
        "dec_layers": stack_init(lambda k: _dec_block_init(k, cfg), ks[3],
                                 cfg.n_layers),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "head": L.dense_init(ks[4], cfg.d_model, cfg.vocab, dtype),
    }


def encode(params, cfg: ModelConfig, src_embeds, *, remat=True, block_k=512):
    b, s, _ = src_embeds.shape
    h = src_embeds.astype(_dtype(cfg)) @ params["frame_proj"]
    h = shard(h, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(hh, lp):
        hn = L.layernorm(hh, lp["norm1"], jnp.zeros_like(lp["norm1"]))
        a, _ = A.gqa_train(lp["attn"], cfg, hn, positions, causal=False,
                           block_k=block_k)
        hh = hh + a
        hn = L.layernorm(hh, lp["norm2"], jnp.zeros_like(lp["norm2"]))
        hh = hh + L.gelu_mlp_apply(lp["ffn"], hn)
        return shard(hh, "batch", None, None), None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = lax.scan(body, h, params["enc_layers"])
    return L.layernorm(h, params["enc_norm"], jnp.zeros_like(params["enc_norm"]))


def _dec_block(lp, cfg, h, positions, enc_out, *, return_cache=False,
               block_k=512):
    hn = L.layernorm(h, lp["norm1"], jnp.zeros_like(lp["norm1"]))
    a, self_kv = A.gqa_train(lp["self_attn"], cfg, hn, positions,
                             return_cache=return_cache, block_k=block_k)
    h = h + a
    hn = L.layernorm(h, lp["norm_x"], jnp.zeros_like(lp["norm_x"]))
    cross_kv = A.gqa_encode_kv(lp["cross_attn"], cfg, enc_out)
    h = h + A.gqa_cross(lp["cross_attn"], cfg, hn, cross_kv, block_k=block_k)
    hn = L.layernorm(h, lp["norm2"], jnp.zeros_like(lp["norm2"]))
    h = h + L.gelu_mlp_apply(lp["ffn"], hn)
    h = shard(h, "batch", None, None)
    return h, (self_kv, cross_kv if return_cache else None)


def encdec_loss(params, cfg: ModelConfig, batch, *, remat=True, block_k=512):
    enc_out = encode(params, cfg, batch["src_embeds"], remat=remat,
                     block_k=block_k)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = params["emb"][tokens].astype(_dtype(cfg))

    def body(hh, lp):
        hh, _ = _dec_block(lp, cfg, hh, positions, enc_out, block_k=block_k)
        return hh, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = lax.scan(body, h, params["dec_layers"])
    h = L.layernorm(h, params["final_norm"], jnp.zeros_like(params["final_norm"]))
    logits = _head(params, cfg, h)
    loss = xent_loss(logits, batch["labels"])
    return loss, {"loss": loss, "xent": loss, "aux": 0.0}


def encdec_prefill(params, cfg: ModelConfig, batch, *, block_k=512):
    """Returns (last logits, cache = (self_kv stacked, cross_kv stacked))."""
    enc_out = encode(params, cfg, batch["src_embeds"], remat=False,
                     block_k=block_k)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = params["emb"][tokens].astype(_dtype(cfg))

    def body(hh, lp):
        hh, caches = _dec_block(lp, cfg, hh, positions, enc_out,
                                return_cache=True, block_k=block_k)
        return hh, caches

    h, (self_kv, cross_kv) = lax.scan(body, h, params["dec_layers"])
    h = L.layernorm(h, params["final_norm"], jnp.zeros_like(params["final_norm"]))
    return _head(params, cfg, h[:, -1]), (self_kv, cross_kv)


def encdec_init_cache(cfg: ModelConfig, b: int, max_len: int, enc_len: int):
    dt = _dtype(cfg)
    n, hkv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    kv = lambda s: (jnp.zeros((n, b, hkv, s, hd), dt),
                    jnp.zeros((n, b, hkv, s, hd), dt))
    return (kv(max_len), kv(enc_len))


def encdec_decode_step(params, cfg: ModelConfig, cache, tokens, kv_len,
                       *, block_k=2048):
    self_kv, cross_kv = cache
    h = params["emb"][tokens].astype(_dtype(cfg))

    def body(hh, xs):
        lp, (sk, sv), (ck, cv) = xs
        hn = L.layernorm(hh, lp["norm1"], jnp.zeros_like(lp["norm1"]))
        a, new_kv = A.gqa_decode(lp["self_attn"], cfg, hn, (sk, sv), kv_len,
                                 block_k=block_k)
        hh = hh + a
        hn = L.layernorm(hh, lp["norm_x"], jnp.zeros_like(lp["norm_x"]))
        hh = hh + A.gqa_cross(lp["cross_attn"], cfg, hn, (ck, cv),
                              block_k=block_k)
        hn = L.layernorm(hh, lp["norm2"], jnp.zeros_like(lp["norm2"]))
        hh = hh + L.gelu_mlp_apply(lp["ffn"], hn)
        return hh, new_kv

    h, new_self = lax.scan(body, h,
                           (params["dec_layers"], self_kv, cross_kv))
    h = L.layernorm(h, params["final_norm"], jnp.zeros_like(params["final_norm"]))
    return _head(params, cfg, h[:, -1]), (new_self, cross_kv)
