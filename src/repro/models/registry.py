"""Architecture registry: config name -> Model bundle (init / loss / prefill /
decode_step / init_cache) + input_specs for every shape cell."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCfg, SHAPES, applicable
from repro.models import transformer as T
from repro.models import encdec as ED
from repro.models import xlstm_model as XL
from repro.models import zamba as ZB

ARCH_IDS = [
    "stablelm-12b", "qwen2.5-32b", "mistral-large-123b", "qwen1.5-32b",
    "llava-next-mistral-7b", "granite-moe-1b-a400m", "deepseek-v3-671b",
    "xlstm-125m", "seamless-m4t-large-v2", "zamba2-1.2b",
]

_MODULES = {
    "stablelm-12b": "stablelm_12b",
    "qwen2.5-32b": "qwen2_5_32b",
    "mistral-large-123b": "mistral_large_123b",
    "qwen1.5-32b": "qwen1_5_32b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-large-v2": "seamless_m4t_large",
    "zamba2-1.2b": "zamba2_1b",
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable            # key -> params
    loss: Callable            # (params, batch) -> (loss, metrics)
    prefill: Callable         # (params, batch) -> (last_logits, cache)
    decode_step: Callable     # (params, cache, tokens, kv_len) -> (logits, cache)
    init_cache: Callable      # (b, max_len) -> cache pytree
    grow_cache: Callable      # (cache, max_len) -> cache padded along seq axis


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE if smoke else mod.CONFIG


def _pad_axis(x, axis, new_len):
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, new_len - x.shape[axis])
    return jnp.pad(x, pads)


def build(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            init=lambda key: T.lm_init(key, cfg),
            loss=lambda p, b, **kw: T.lm_loss(p, cfg, b, **kw),
            prefill=lambda p, b, **kw: T.lm_prefill(p, cfg, b, **kw),
            decode_step=lambda p, c, t, kl, **kw: T.lm_decode_step(
                p, cfg, c, t, kl, **kw),
            init_cache=lambda b, ml: T.lm_init_cache(cfg, b, ml),
            grow_cache=lambda c, ml: T.lm_grow_cache(cfg, c, ml),
        )
    if fam == "ssm_xlstm":
        return Model(
            cfg=cfg,
            init=lambda key: XL.xlstm_init(key, cfg),
            loss=lambda p, b, **kw: XL.xlstm_loss(p, cfg, b, **kw),
            prefill=lambda p, b, **kw: XL.xlstm_prefill(p, cfg, b, **kw),
            decode_step=lambda p, c, t, kl, **kw: XL.xlstm_decode_step(
                p, cfg, c, t, kl, **kw),
            init_cache=lambda b, ml: XL.xlstm_init_cache(cfg, b, ml),
            grow_cache=lambda c, ml: c,  # constant-size recurrent state
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: ZB.zamba_init(key, cfg),
            loss=lambda p, b, **kw: ZB.zamba_loss(p, cfg, b, **kw),
            prefill=lambda p, b, **kw: ZB.zamba_prefill(p, cfg, b, **kw),
            decode_step=lambda p, c, t, kl, **kw: ZB.zamba_decode_step(
                p, cfg, c, t, kl, **kw),
            init_cache=lambda b, ml: ZB.zamba_init_cache(cfg, b, ml),
            grow_cache=lambda c, ml: {
                "mamba": c["mamba"],
                "attn_kv": tuple(_pad_axis(x, 3, ml) for x in c["attn_kv"])},
        )
    if fam == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: ED.encdec_init(key, cfg),
            loss=lambda p, b, **kw: ED.encdec_loss(p, cfg, b, **kw),
            prefill=lambda p, b, **kw: ED.encdec_prefill(p, cfg, b, **kw),
            decode_step=lambda p, c, t, kl, **kw: ED.encdec_decode_step(
                p, cfg, c, t, kl, **kw),
            init_cache=lambda b, ml: ED.encdec_init_cache(
                cfg, b, ml, max(ml // 8, 8)),
            grow_cache=lambda c, ml: (
                tuple(jax.tree.map(lambda x: _pad_axis(x, 3, ml), c[0])),
                c[1]),
        )
    raise ValueError(f"unknown family {fam!r}")


FRONTEND_DIM = 1024


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell —
    weak-type-correct, shardable, no device allocation."""
    if not applicable(cfg, shape):
        raise ValueError(f"{cfg.name} skips {shape.name} (DESIGN.md §4)")
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((gb, s), i32), "labels": sds((gb, s), i32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds((gb, cfg.n_patches, FRONTEND_DIM), f32)
        if cfg.family == "encdec":
            batch["src_embeds"] = sds((gb, s, FRONTEND_DIM), f32)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": sds((gb, s), i32)}
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds((gb, cfg.n_patches, FRONTEND_DIM), f32)
        if cfg.family == "encdec":
            batch["src_embeds"] = sds((gb, s, FRONTEND_DIM), f32)
        return {"batch": batch}
    # decode: one new token against a max_len cache
    model = build(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(gb, s))
    return {"cache": cache,
            "tokens": sds((gb, 1), i32),
            "kv_len": sds((gb,), i32)}
