"""Mixture-of-Experts FFN with sort-based (dropping) dispatch.

Dispatch avoids the O(T·E·C) one-hot blow-up: token-expert assignments are
argsorted by expert, positions within each expert computed from the sorted
run starts, tokens over capacity dropped, and experts applied as one batched
[E, C, d] x [E, d, f] contraction (EP: the E dim shards over 'expert').

MoE is also where the paper's asymmetric-sharing model shows up *inside* the
model: each data shard touches only its routed experts' parameters, so
cross-pod synchronization of expert banks is sparse — exactly what the
sRSP-style selective delta sync exploits (distributed/hier_sync.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.kernels.topk_router.ref import topk_router_ref
from repro.sharding import shard


def moe_init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], d, e, jnp.float32),
        "experts_w1": jax.random.uniform(ks[1], (e, d, f), dtype,
                                         -(1 / d) ** 0.5, (1 / d) ** 0.5),
        "experts_w3": jax.random.uniform(ks[2], (e, d, f), dtype,
                                         -(1 / d) ** 0.5, (1 / d) ** 0.5),
        "experts_w2": jax.random.uniform(ks[3], (e, f, d), dtype,
                                         -(1 / f) ** 0.5, (1 / f) ** 0.5),
    }
    if m.n_shared:
        p["shared"] = L.swiglu_init(ks[4], d, m.n_shared * m.d_expert, dtype)
    return p


def _dispatch_one_group(x2d, weights, idx, e, k, cap):
    """Sort-based dispatch/combine for ONE token group.

    Returns (buf [e, cap, d], combine closure inputs).  Pure local math —
    vmapping this over groups (groups aligned to data shards) keeps the
    dispatch communication-free under GSPMD (§Perf hillclimb B)."""
    t, d = x2d.shape
    eflat = idx.reshape(-1)                               # [t*k]
    order = jnp.argsort(eflat, stable=True)
    sorted_e = eflat[order]
    wflat = weights.reshape(-1)[order]                    # sorted order!
    token_of = order // k
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, e * cap)  # drop slot
    xs = x2d[token_of]                                    # [t*k, d]
    buf = jnp.zeros((e * cap, d), x2d.dtype).at[dest].set(
        jnp.where(keep[:, None], xs, 0), mode="drop")
    return buf.reshape(e, cap, d), (token_of, wflat, keep, dest)


def _combine_one_group(out, meta, t, e, cap):
    token_of, wflat, keep, dest = meta
    out = out.reshape(e * cap, -1)
    gathered = jnp.where(keep[:, None],
                         out[jnp.clip(dest, 0, e * cap - 1)], 0)
    return jnp.zeros((t, out.shape[-1]), jnp.float32).at[token_of].add(
        gathered.astype(jnp.float32) * wflat[:, None])


def moe_apply(p, cfg: ModelConfig, x2d: jnp.ndarray):
    """x2d [T, d] -> (y [T, d], aux_loss scalar, expert_counts [E]).

    Group-blocked dispatch: tokens are split into `dispatch_groups` groups
    (sharding-aligned with the data axis), each group sorts and capacity-
    packs locally (GShard/Switch style).  Expert weights stay EP-sharded;
    the only cross-shard communication is the combine reduction."""
    m = cfg.moe
    t, d = x2d.shape
    e, k = m.n_experts, m.top_k
    g = m.dispatch_groups
    while g > 1 and t % g != 0:
        g //= 2
    tg = t // g
    cap = max(int(tg * k / e * m.capacity_factor), 4)
    cap = min(cap, tg)

    logits = (x2d.astype(jnp.float32)) @ p["router"]      # [T, E]
    weights, idx = topk_router_ref(logits, k)             # [T,k] f32 / i32

    # ---- load-balance aux loss (Switch-style) ----
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)                          # mean router prob
    onehot_top1 = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=0)                    # token fraction
    aux = m.aux_loss_coef * e * jnp.sum(me * ce)

    xg = x2d.reshape(g, tg, d)
    wg = weights.reshape(g, tg, k)
    ig = idx.reshape(g, tg, k)
    buf, meta = jax.vmap(
        lambda xx, ww, ii: _dispatch_one_group(xx, ww, ii, e, k, cap)
    )(xg, wg, ig)
    buf = shard(buf, "batch", None, None, None)           # [g, e, cap, d]

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["experts_w1"])) \
        * jnp.einsum("gecd,edf->gecf", buf, p["experts_w3"])
    out = jnp.einsum("gecf,efd->gecd", h, p["experts_w2"])
    out = shard(out, "batch", None, None, None)

    y = jax.vmap(lambda oo, mm: _combine_one_group(oo, mm, tg, e, cap))(
        out, meta)
    y = y.reshape(t, d)

    if m.n_shared:
        y = y + L.swiglu_apply(p["shared"], x2d).astype(jnp.float32)

    counts = jnp.sum(jax.nn.one_hot(idx.reshape(-1), e, dtype=jnp.float32),
                     axis=0)
    return y.astype(x2d.dtype), aux, counts
