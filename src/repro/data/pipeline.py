"""Synthetic sharded token pipeline with host-side prefetch.

Deterministic per-step batches (seeded, zipf-ish marginal over the vocab so
loss curves are non-trivial), produced on a background thread and
device_put with the active mesh's batch sharding — a stand-in for a real
corpus reader with identical interface.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding import active_mesh, resolve


class TokenPipeline:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 extras: Optional[dict] = None, prefetch: int = 2):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.extras = extras or {}
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _make(self, step: int) -> dict:
        rng = np.random.default_rng(self.seed * 100003 + step)
        # zipf-ish marginal: heavy head, long tail
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        tokens = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        # inject local structure (bigram repeats) so models can learn
        tokens[:, 1::7] = tokens[:, 0:-1:7]
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        for name, shape in self.extras.items():
            out[name] = rng.normal(size=(self.batch, *shape)).astype(np.float32)
        return out

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            try:
                self._q.put(self._make(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        host = self._q.get()
        mesh = active_mesh()
        if mesh is None:
            return jax.tree.map(jnp.asarray, host)
        spec = resolve("batch")
        def put(x):
            s = NamedSharding(mesh, P(spec[0], *([None] * (x.ndim - 1))))
            return jax.device_put(x, s)
        return jax.tree.map(put, host)

    def close(self):
        self._stop.set()
