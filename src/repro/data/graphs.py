"""Synthetic CSR graphs standing in for the paper's DIMACS inputs (§5.1).

This environment is offline, so we generate graphs with the same structural
character as the ones the paper uses:

  * ``collab_like``  — power-law collaboration network (cond-mat-2003; used
    by PageRank in the paper): preferential attachment, heavy-tailed degree
    distribution -> strong per-chunk work imbalance.
  * ``road_like``    — sparse near-planar grid with a small fraction of
    shortcut edges (USA-road-d.BAY; used by SSSP).  Shortcuts keep the
    diameter (== SSSP round count) manageable in the offline simulator;
    the substitution is documented in EXPERIMENTS.md.
  * ``router_like``  — power-law with lower attachment (caidaRouterLevel;
    used by MIS).

All graphs are undirected (symmetrized), weights uniform in [1, 16).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class CSRGraph(NamedTuple):
    indptr: np.ndarray   # [n+1] int32
    indices: np.ndarray  # [nnz] int32
    weights: np.ndarray  # [nnz] int32
    name: str

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def nnz(self) -> int:
        return len(self.indices)

    @property
    def degrees(self) -> np.ndarray:
        return (self.indptr[1:] - self.indptr[:-1]).astype(np.int32)


def _to_csr(n: int, src: np.ndarray, dst: np.ndarray, rng, name: str) -> CSRGraph:
    # symmetrize + dedup + drop self loops
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    keep = u != v
    u, v = u[keep], v[keep]
    key = u.astype(np.int64) * n + v
    _, idx = np.unique(key, return_index=True)
    u, v = u[idx], v[idx]
    order = np.argsort(u, kind="stable")
    u, v = u[order], v[order]
    indptr = np.zeros(n + 1, np.int32)
    np.add.at(indptr, u + 1, 1)
    indptr = np.cumsum(indptr, dtype=np.int64).astype(np.int32)
    w = rng.integers(1, 16, size=len(v)).astype(np.int32)
    return CSRGraph(indptr, v.astype(np.int32), w, name)


def collab_like(n: int = 8192, m: int = 6, seed: int = 0) -> CSRGraph:
    """Preferential-attachment graph (Barabási–Albert style)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m))
    src, dst = [], []
    repeated: list[int] = list(range(m))
    for v in range(m, n):
        picks = rng.choice(len(repeated), size=m, replace=True)
        chosen = {repeated[p] for p in picks}
        for t in chosen:
            src.append(v)
            dst.append(t)
            repeated.append(t)
            repeated.append(v)
    return _to_csr(n, np.array(src, np.int64), np.array(dst, np.int64), rng,
                   f"collab_like_n{n}")


def router_like(n: int = 8192, seed: int = 1) -> CSRGraph:
    g = collab_like(n, m=2, seed=seed)
    return g._replace(name=f"router_like_n{n}")


def road_like(n: int = 16384, shortcut_frac: float = 0.01, seed: int = 2) -> CSRGraph:
    """Grid road network with a few express shortcuts (keeps diameter small)."""
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(n))
    n = side * side
    ids = np.arange(n).reshape(side, side)
    src, dst = [], []
    # 4-neighborhood with 10% random removals (non-grid irregularity)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()], 1)
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()], 1)
    edges = np.concatenate([right, down], 0)
    keep = rng.random(len(edges)) > 0.1
    edges = edges[keep]
    src, dst = edges[:, 0], edges[:, 1]
    # express shortcuts
    k = int(n * shortcut_frac)
    s = rng.integers(0, n, k)
    d = rng.integers(0, n, k)
    return _to_csr(n, np.concatenate([src, s]).astype(np.int64),
                   np.concatenate([dst, d]).astype(np.int64), rng,
                   f"road_like_n{n}")


GRAPHS = {
    "collab_like": collab_like,
    "router_like": router_like,
    "road_like": road_like,
}
