"""Analytic roofline model (EXPERIMENTS.md §Roofline).

XLA:CPU's cost_analysis does not multiply through `while` trip counts, so a
scan-over-layers program under-reports FLOPs/bytes by ~L x n_micro (verified
in EXPERIMENTS.md §Dry-run).  The roofline terms are therefore derived
*analytically* from the known sharding plan and per-arch operator counts —
the same napkin math the §Perf loop uses — while the compiled HLO supplies
structural evidence (which collectives exist in each loop body, per-device
buffer sizes).

All terms are per-device-per-step seconds on TPU v5e-class constants.

Sharding plan assumed (baseline; knobs mirror the hillclimb changes):
  batch over ('pod','data'); params FSDP over 'data' + TP over 'model';
  train remat = full (3 weight passes: fwd, recompute, bwd);
  MoE: experts over 'model' (EP), sort-based dispatch (all-to-all);
  decode: TP all-reduce per layer, KV cache local to its shard.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ShapeCfg

PEAK = 197e12
HBM = 819e9
LINK = 50e9
B2 = 2  # bf16 bytes


@dataclasses.dataclass
class Plan:
    dp: int = 16            # data-parallel ways (x pod for multi)
    tp: int = 16
    pods: int = 1
    remat_passes: int = 3   # fwd + recompute + bwd weight passes (full remat)
    fsdp: bool = True
    moe_a2a_factor: float = 8.0   # dispatch+combine, fwd+bwd, ring 2x
    tp_collectives_train: int = 6 # ar per layer (2 fwd, 2 bwd, 2 recompute)
    tp_collectives_inf: int = 2
    gather_weights_decode: bool = True  # FSDP gather on every decode step
    sp: bool = False        # sequence parallel: AR -> RS+AG (half the bytes)

    @property
    def ring(self) -> float:
        return 1.0 if self.sp else 2.0

    @property
    def n_dev(self):
        return self.dp * self.tp * self.pods

    @property
    def dp_total(self):
        return self.dp * self.pods


# §Perf hillclimb plan variants (EXPERIMENTS.md)
PLANS = {
    "baseline": Plan(),
    "sp": Plan(sp=True),
    "sp_dots": Plan(sp=True, remat_passes=2, tp_collectives_train=4),
    "sp_dots_mb64": Plan(sp=True, remat_passes=2, tp_collectives_train=4),
    "grp": Plan(moe_a2a_factor=4.0),
    "grp_sp_dots": Plan(sp=True, remat_passes=2, tp_collectives_train=4,
                        moe_a2a_factor=4.0),
    "serve_replicated": Plan(gather_weights_decode=False),
}


def _attn_flops(cfg: ModelConfig, tokens: float, ctx: float, mult: float):
    """2*2*H*hd per (token, ctx) MAC pair; causal halves train/prefill."""
    if cfg.family == "ssm_xlstm":
        return 0.0
    L = cfg.n_layers if cfg.family != "hybrid" else max(
        1, cfg.n_layers // max(cfg.attn_every, 1))
    h = cfg.n_heads
    hd = (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
          if cfg.mla else cfg.hd)
    return mult * 2 * tokens * ctx * h * hd * L


def roofline(cfg: ModelConfig, shape: ShapeCfg, plan: Plan) -> dict:
    gb, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    n_dev = plan.n_dev
    P = cfg.param_count()
    Pa = cfg.active_param_count()
    d = cfg.d_model
    L = cfg.n_layers + cfg.enc_layers
    n_micro = (gb // cfg.microbatch if (kind == "train" and cfg.microbatch)
               else 1)

    if kind == "train":
        tokens = gb * S
        flop_mult, ctx, attn_mult = 6, S / 2, 3  # fwd+bwd
    elif kind == "prefill":
        tokens = gb * S
        flop_mult, ctx, attn_mult = 2, S / 2, 1
    else:
        tokens = gb
        flop_mult, ctx, attn_mult = 2, S, 1

    tokens_local = tokens / plan.dp_total
    useful = flop_mult * Pa * tokens + _attn_flops(cfg, tokens, ctx, attn_mult)
    t_compute = useful / n_dev / PEAK

    # ---- HBM traffic per device ----
    if kind == "train":
        # weights: every pass materializes + reads the full TP shard of each
        # layer (FSDP all-gathered); optimizer touches the local shard.
        w_bytes = plan.remat_passes * n_micro * (Pa * B2) / plan.tp
        opt_bytes = (P / n_dev) * (2 + 4 + 4 + 4 + 2)
        act_bytes = tokens_local * d * L * B2 * 10  # fwd+bwd+recompute r/w
        mem = w_bytes + opt_bytes + act_bytes
    elif kind == "prefill":
        mem = (Pa * B2) / plan.tp + tokens_local * d * L * B2 * 4
        # blockwise attention re-streams KV once per layer
        mem += tokens_local * (cfg.n_kv_heads * cfg.hd if not cfg.mla
                               else 576) * L * B2 * 2
    else:
        w = (Pa * B2) / plan.tp
        if plan.gather_weights_decode and plan.fsdp:
            w = (Pa * B2) / plan.tp  # gathered then read once
        kv_dim = (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                  if cfg.mla else 2 * cfg.n_kv_heads * cfg.hd)
        if cfg.family == "ssm_xlstm":
            kv_bytes = 0.0
        elif cfg.family == "hybrid":
            napp = max(1, cfg.n_layers // max(cfg.attn_every, 1))
            kv_bytes = gb * S * 2 * cfg.n_kv_heads * cfg.hd * napp * B2 / n_dev
        else:
            kv_bytes = gb * S * kv_dim * L * B2 / n_dev
        mem = w + kv_bytes + tokens_local * d * L * B2 * 4
    t_memory = mem / HBM

    # ---- collective traffic per device ----
    coll = 0.0
    act_tok = tokens_local * d * B2
    n_tp_layers = L
    if kind == "train":
        coll += plan.tp_collectives_train * n_tp_layers * act_tok \
            * plan.ring
        if plan.fsdp:
            coll += plan.remat_passes * n_micro * (Pa * B2) / plan.tp  # AG
            coll += (P * B2) / plan.tp                                 # RS grads
        if plan.pods > 1:
            coll += 2 * (P * B2) / (plan.dp * plan.tp)  # cross-pod grad AR
        if cfg.moe is not None:
            coll += plan.moe_a2a_factor * tokens_local * d * B2
    else:
        coll += plan.tp_collectives_inf * n_tp_layers * act_tok * plan.ring
        if cfg.moe is not None:
            coll += 4 * tokens_local * d * B2
        if kind == "decode" and plan.gather_weights_decode and plan.fsdp:
            coll += (Pa * B2) / plan.tp
    t_coll = coll / LINK

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bound = max(terms, key=terms.get)
    t_bound = max(terms.values()) or 1e-30
    return {
        "arch": cfg.name, "shape": shape.name,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bound": bound,
        "useful_flops": useful, "mem_bytes_dev": mem, "coll_bytes_dev": coll,
        "roofline_frac": t_compute / t_bound,
        "n_micro": n_micro,
    }
