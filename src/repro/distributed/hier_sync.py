"""sRSP-style asymmetric cross-pod synchronization (the paper's technique as
a framework feature — DESIGN.md §2).

Scope mapping: within-pod gradient sync is "local scope" (cheap, every
step, implicit in pjit).  Cross-pod sync is deferred local-SGD style; each
pod is the *local sharer* of the parameter blocks its batch actually
touched.  A remote acquire (periodic global sync, eval, checkpoint,
elastic rejoin) performs the *selective flush*: only blocks dirtied since
the last release are compacted (Pallas selective_flush = the sFIFO drain)
and exchanged over the 'pod' axis, instead of a full-parameter all-reduce
(the RSP-baseline analogue).  A PA-TBL-style promotion mask marks blocks
that must be re-fetched from global scope on next use.

Where it wins: sparsely-updated banks — MoE expert weights (each pod's
batch routes to a subset of experts) and embedding rows.  Dense layers mark
everything dirty and selective sync degrades gracefully to a full sync
(tracked and reported, like RSP == sRSP when every cache line is dirty).

All ops are pure and run under shard_map over the 'pod' mesh axis; the same
code drives the byte-accounting benchmark (benchmarks/delta_sync_bench.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat shim: `jax.shard_map` (new API, `check_vma` kwarg)
    landed after 0.4.x; fall back to `jax.experimental.shard_map.shard_map`
    (old API, `check_rep` kwarg) on installed versions that lack it."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)

from repro.distributed import compress as CMP
from repro.kernels.selective_flush.ref import (selective_flush_ref,
                                               selective_apply_ref)
from repro.kernels.selective_flush import selective_flush


class BankSyncState(NamedTuple):
    """Per-pod state for one parameter bank [n_blocks, block_size]."""
    ref: jnp.ndarray          # snapshot at last global sync ("L2 copy")
    ef: jnp.ndarray           # error-feedback residual (compression)
    promoted: jnp.ndarray     # [n_blocks] bool — PA-TBL analogue
    syncs: jnp.ndarray        # [] i32 global syncs performed
    bytes_selective: jnp.ndarray  # [] f32 bytes a selective sync moved
    bytes_full: jnp.ndarray       # [] f32 bytes a full sync would move


def bank_init(bank: jnp.ndarray) -> BankSyncState:
    n, b = bank.shape
    z = jnp.float32(0.0)
    return BankSyncState(ref=bank.astype(jnp.float32),
                         ef=jnp.zeros((n, b), jnp.float32),
                         promoted=jnp.zeros((n,), bool),
                         syncs=jnp.int32(0),
                         bytes_selective=z, bytes_full=z)


def dirty_mask(bank: jnp.ndarray, st: BankSyncState, tol: float = 0.0
               ) -> jnp.ndarray:
    d = jnp.abs(bank.astype(jnp.float32) - st.ref)
    return jnp.max(d, axis=-1) > tol


def selective_global_sync(bank: jnp.ndarray, st: BankSyncState,
                          *, axis_name: str = "pod", max_dirty: int,
                          use_int8: bool = False, use_pallas: bool = False
                          ) -> Tuple[jnp.ndarray, BankSyncState]:
    """The remote acquire: union dirty set across pods, flush only those
    blocks, average deltas, promote.  Runs inside shard_map over `axis_name`.

    bank [n_blocks, bs] — this pod's current values."""
    n_blocks, bs = bank.shape
    n_pods = jax.lax.psum(1, axis_name)
    delta = bank.astype(jnp.float32) - st.ref

    mine = dirty_mask(bank, st)
    union = jax.lax.psum(mine.astype(jnp.int32), axis_name) > 0   # probe bcast
    # deterministic shared index list (same on every pod): first max_dirty
    # union-dirty block ids, -1 padded.  Overflow -> sticky full sync.
    order = jnp.argsort(~union, stable=True)          # dirty ids first
    idx = jnp.where(jnp.arange(n_blocks) < max_dirty, order, -1)[:max_dirty]
    idx = jnp.where(union[jnp.clip(idx, 0, n_blocks - 1)], idx, -1)
    overflow = jnp.sum(union) > max_dirty

    flush = selective_flush if use_pallas else (
        lambda b, i: selective_flush_ref(b, i))
    if use_int8:
        q, scale, ef_state = CMP.compress_blocks(
            delta, CMP.EFState(st.ef), idx)
        q_sum = jax.lax.psum(dequant := CMP.dequantize_int8(q, scale),
                             axis_name)
        payload = q_sum / n_pods
        ef = ef_state.err
        moved = q.size * 1 + scale.size * 4
    else:
        payload = jax.lax.psum(flush(delta, idx), axis_name) / n_pods
        ef = st.ef
        moved = payload.size * 4

    # fall back to full sync on overflow (conservative, like LR-TBL eviction)
    full_mean = st.ref + jax.lax.psum(delta, axis_name) / n_pods
    merged = selective_apply_ref(st.ref, st.ref[jnp.clip(idx, 0, n_blocks - 1)]
                                 + payload, idx)
    new_bank = jnp.where(overflow, full_mean, merged)
    moved_bytes = jnp.where(overflow, jnp.float32(delta.size * 4),
                            jnp.float32(moved + n_blocks // 8))

    new_st = BankSyncState(
        ref=new_bank,
        ef=ef,
        promoted=union,  # PA-TBL: these blocks were remotely written
        syncs=st.syncs + 1,
        bytes_selective=st.bytes_selective + moved_bytes,
        bytes_full=st.bytes_full + jnp.float32(delta.size * 4),
    )
    return new_bank.astype(bank.dtype), new_st


def full_global_sync(bank: jnp.ndarray, st: BankSyncState,
                     *, axis_name: str = "pod"
                     ) -> Tuple[jnp.ndarray, BankSyncState]:
    """RSP-baseline analogue: always move the whole bank."""
    n_pods = jax.lax.psum(1, axis_name)
    delta = bank.astype(jnp.float32) - st.ref
    new_bank = st.ref + jax.lax.psum(delta, axis_name) / n_pods
    sz = jnp.float32(delta.size * 4)
    return new_bank.astype(bank.dtype), st._replace(
        ref=new_bank, syncs=st.syncs + 1,
        bytes_selective=st.bytes_selective + sz,
        bytes_full=st.bytes_full + sz)


def make_pod_sync(mesh: Mesh, n_blocks: int, block_size: int,
                  *, max_dirty: int, use_int8: bool = False,
                  selective: bool = True):
    """shard_map-wrapped sync over the 'pod' axis: bank/state are per-pod
    (sharded on a leading pod dim)."""
    fn = functools.partial(
        selective_global_sync if selective else full_global_sync,
        axis_name="pod",
        **({"max_dirty": max_dirty, "use_int8": use_int8} if selective else {}))

    state_specs = BankSyncState(
        ref=P("pod", None, None), ef=P("pod", None, None),
        promoted=P("pod", None), syncs=P("pod"),
        bytes_selective=P("pod"), bytes_full=P("pod"))

    @functools.partial(
        _shard_map, mesh=mesh,
        in_specs=(P("pod", None, None), state_specs),
        out_specs=(P("pod", None, None), state_specs))
    def sync(bank_stacked, st_stacked):
        bank = bank_stacked[0]
        st = jax.tree.map(lambda x: x[0], st_stacked)
        new_bank, new_st = fn(bank, st)
        return (new_bank[None],
                jax.tree.map(lambda x: x[None], new_st))

    return sync
