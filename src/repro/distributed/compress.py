"""Gradient/delta compression for cross-pod (DCI) traffic: per-block int8
quantization with error feedback.

Used by the sRSP-style selective cross-pod sync (hier_sync.py): the flushed
dirty-block payload is quantized before the 'pod'-axis collective, and the
quantization error is fed back into the next delta (standard EF-SGD), so
the compression is unbiased over time."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    err: jnp.ndarray  # [n_blocks, block_size] f32 residual


def ef_init(n_blocks: int, block_size: int) -> EFState:
    return EFState(err=jnp.zeros((n_blocks, block_size), jnp.float32))


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row symmetric int8.  x [n, d] -> (q int8 [n, d], scale f32 [n])."""
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale[:, None]


def compress_blocks(delta: jnp.ndarray, ef: EFState, idx: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, EFState]:
    """delta [n_blocks, bs]; idx [max_dirty] block ids (-1 pad).
    Returns (q [max_dirty, bs] int8, scales [max_dirty], ef')."""
    safe = jnp.clip(idx, 0, delta.shape[0] - 1)
    valid = (idx >= 0)[:, None]
    payload = (delta[safe] + ef.err[safe]) * valid
    q, scale = quantize_int8(payload)
    recon = dequantize_int8(q, scale)
    new_err = ef.err.at[safe].set(jnp.where(valid, payload - recon,
                                            ef.err[safe]))
    return q, scale, EFState(err=new_err)


def compressed_bytes(max_dirty: int, block_size: int) -> int:
    return max_dirty * block_size * 1 + max_dirty * 4  # int8 payload + scales
