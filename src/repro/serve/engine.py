"""Batched serving engine: prefill + greedy decode with ragged lengths and
slot-based continuous batching (a finished slot is refilled from the queue
without draining the batch)."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class Engine:
    """Fixed-slot continuous-batching engine.

    `slots` sequences decode together in ONE jitted vmapped step; each
    slot's cache is a B=1 cache pytree stacked on a fresh leading axis,
    which keeps the layout model-agnostic (transformer caches batch on
    axis 1, recurrent states elsewhere — the engine never needs to know).
    When a slot finishes it is refilled from the queue immediately — the
    other slots keep decoding, nothing drains.  Per-slot kv_len makes the
    ragged lengths explicit; greedy decode per slot is independent of its
    neighbors, so outputs are identical to running requests one at a time
    (tests/test_serve_engine.py pins this against a serial reference)."""

    def __init__(self, model: Model, params, *, max_len: int = 512,
                 slots: int = 4, eos: int = -1):
        self.model, self.params = model, params
        self.max_len, self.slots, self.eos = max_len, slots, eos
        self._decode = jax.jit(
            lambda p, c, t, kl: model.decode_step(p, c, t, kl))
        # one decode trip for ALL slots: vmap over the stacked slot axis
        self._decode_many = jax.jit(jax.vmap(
            lambda p, c, t, kl: model.decode_step(p, c, t, kl),
            in_axes=(None, 0, 0, 0)))
        self._insert = jax.jit(
            lambda stk, one, i: jax.tree.map(
                lambda s, o: jax.lax.dynamic_update_index_in_dim(s, o, i, 0),
                stk, one))

    def _prefill_one(self, prompt: np.ndarray):
        batch = {"tokens": jnp.asarray(prompt[None])}
        logits, cache = self.model.prefill(self.params, batch)
        cache = self.model.grow_cache(cache, self.max_len)
        return logits, cache

    def generate(self, requests: List[Request]) -> List[Request]:
        """Greedy generation with slot-based continuous batching."""
        queue = list(range(len(requests)))
        req = [None] * self.slots      # request index occupying each slot
        toks: List[Optional[list]] = [None] * self.slots
        left = np.zeros(self.slots, np.int64)    # new tokens still allowed
        kv = np.ones(self.slots, np.int64)       # kv_len per slot
        cur = np.zeros(self.slots, np.int64)     # last sampled token
        zero = jax.tree.map(lambda x: x[None],
                            self.model.init_cache(1, self.max_len))
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.slots,) + x.shape[1:]), zero)

        def finish(i):
            requests[req[i]].out = np.asarray(toks[i], np.int32)
            req[i] = None

        while True:
            # refill every free slot before the next batched decode trip
            for i in range(self.slots):
                while req[i] is None and queue:
                    r = requests[queue[0]]
                    logits, cache = self._prefill_one(r.prompt)
                    req[i], toks[i] = queue.pop(0), [int(jnp.argmax(
                        logits[0]))]
                    kv[i], cur[i] = len(r.prompt), toks[i][0]
                    left[i] = r.max_new_tokens - 1
                    if left[i] <= 0 or cur[i] == self.eos \
                            or kv[i] >= self.max_len:
                        finish(i)           # done at prefill; slot frees
                        continue
                    stacked = self._insert(stacked, cache, jnp.int32(i))
            live = [i for i in range(self.slots) if req[i] is not None]
            if not live:
                break
            t = jnp.asarray(cur[:, None, None], jnp.int32)   # [slots, 1, 1]
            kl = jnp.asarray(np.clip(kv, 1, self.max_len - 1)[:, None],
                             jnp.int32)                      # [slots, 1]
            logits, stacked = self._decode_many(self.params, stacked, t, kl)
            nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
            for i in live:
                toks[i].append(int(nxt[i]))
                cur[i], kv[i], left[i] = nxt[i], kv[i] + 1, left[i] - 1
                if left[i] <= 0 or cur[i] == self.eos \
                        or kv[i] >= self.max_len:
                    finish(i)
        return requests


def throughput_bench(model: Model, params, batch: int, seq: int,
                     new_tokens: int = 8):
    """Batched prefill+decode timing (used by benchmarks)."""
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (batch, seq)),
                         jnp.int32)
    t0 = time.perf_counter()
    logits, cache = model.prefill(params, {"tokens": tokens})
    cache = model.grow_cache(cache, seq + new_tokens)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    step = jax.jit(lambda p, c, t, kl: model.decode_step(p, c, t, kl))
    kv_len = jnp.full((batch,), seq, jnp.int32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(new_tokens):
        logits, cache = step(params, cache, tok, kv_len + i)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    return {"prefill_s": t_prefill, "decode_s_per_tok": t_decode / new_tokens,
            "decode_tok_s": batch * new_tokens / t_decode}
