"""Batched serving engine: prefill + greedy decode with ragged lengths and
slot-based continuous batching (a finished slot is refilled from the queue
without draining the batch)."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model


@dataclasses.dataclass
class Request:
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class Engine:
    """Fixed-slot engine; prompts are right-aligned into a shared cache."""

    def __init__(self, model: Model, params, *, max_len: int = 512,
                 slots: int = 4, eos: int = -1):
        self.model, self.params = model, params
        self.max_len, self.slots, self.eos = max_len, slots, eos
        self._decode = jax.jit(
            lambda p, c, t, kl: model.decode_step(p, c, t, kl))

    def _prefill_one(self, prompt: np.ndarray):
        batch = {"tokens": jnp.asarray(prompt[None])}
        logits, cache = self.model.prefill(self.params, batch)
        cache = self.model.grow_cache(cache, self.max_len)
        return logits, cache

    def generate(self, requests: List[Request]) -> List[Request]:
        """Greedy generation, one slot at a time prefilled, decode batched
        per-slot (CPU-scale correctness harness; the dry-run cells cover the
        production batched-decode lowering)."""
        for r in requests:
            logits, cache = self._prefill_one(r.prompt)
            toks = [int(jnp.argmax(logits[0]))]
            kv_len = len(r.prompt)
            for _ in range(r.max_new_tokens - 1):
                t = jnp.asarray([[toks[-1]]], jnp.int32)
                logits, cache = self._decode(self.params, cache, t,
                                             jnp.asarray([kv_len], jnp.int32))
                kv_len += 1
                nxt = int(jnp.argmax(logits[0]))
                toks.append(nxt)
                if nxt == self.eos:
                    break
            r.out = np.asarray(toks, np.int32)
        return requests


def throughput_bench(model: Model, params, batch: int, seq: int,
                     new_tokens: int = 8):
    """Batched prefill+decode timing (used by benchmarks)."""
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (batch, seq)),
                         jnp.int32)
    t0 = time.perf_counter()
    logits, cache = model.prefill(params, {"tokens": tokens})
    cache = model.grow_cache(cache, seq + new_tokens)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    step = jax.jit(lambda p, c, t, kl: model.decode_step(p, c, t, kl))
    kv_len = jnp.full((batch,), seq, jnp.int32)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(new_tokens):
        logits, cache = step(params, cache, tok, kv_len + i)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    return {"prefill_s": t_prefill, "decode_s_per_tok": t_decode / new_tokens,
            "decode_tok_s": batch * new_tokens / t_decode}
