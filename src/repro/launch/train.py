"""Training driver.

CPU-scale example:
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 20 --batch 8 --seq 128

Cluster usage (documented; the dry-run validates the lowering): run one
process per host with jax.distributed.initialize(), pass --mesh single or
--mesh multi, and the same script pjit-shards over the production mesh."""
from __future__ import annotations

import argparse
import json

import jax

from repro.models.registry import get_config
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "debug", "single", "multi"])
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (FT demo)")
    args = ap.parse_args()

    mesh = None
    if args.mesh == "debug":
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh()
    elif args.mesh in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    cfg = get_config(args.arch, smoke=args.smoke)
    tcfg = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                       lr=args.lr, ckpt_dir=args.ckpt_dir,
                       microbatch=args.microbatch)
    trainer = Trainer(cfg, tcfg, mesh=mesh)
    trainer.run(fail_at=args.fail_at)
    for m in trainer.metrics_log:
        print(json.dumps(m))
    if trainer.metrics_log:
        first = trainer.metrics_log[0].get("loss")
        last = trainer.metrics_log[-1].get("loss")
        print(f"loss {first:.4f} -> {last:.4f}  restarts={trainer.restarts}")


if __name__ == "__main__":
    main()
