"""Input/parameter sharding specs for each (arch x shape x mesh) dry-run cell.

Cache layout rules (DESIGN.md §6, baseline — §Perf iterates from here):
  * batch dims shard over ('pod','data') when divisible;
  * KV-cache heads shard over 'model' when n_kv_heads divides;
    otherwise the cache *sequence* axis shards over 'model'
    (distributed flash-decode: softmax psums are tiny);
  * long_500k (batch 1): sequence shards over ('data','model') or 'data'
    so a 512k cache spreads across the pod;
  * Mamba2 / xLSTM recurrent states: inner channel dims over 'model' when
    divisible, batch over data.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCfg
from repro.models import registry as R
from repro.sharding import make_rules, param_specs


def _ax(rules, name):
    ax = rules.get(name)
    if ax is None:
        return None
    return ax[0] if len(ax) == 1 else tuple(ax)


def _mesh_size(mesh: Mesh, logical_axes) -> int:
    if logical_axes is None:
        return 1
    names = logical_axes if isinstance(logical_axes, tuple) else (logical_axes,)
    n = 1
    for a in names:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    return n


def batch_axis(mesh: Mesh, rules, gb: int):
    bat = _ax(rules, "batch")
    return bat if gb % _mesh_size(mesh, bat) == 0 else None


def batch_specs(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh) -> Any:
    """Spec tree matching registry.input_specs structure for train/prefill."""
    rules = make_rules(mesh)
    bat = batch_axis(mesh, rules, shape.global_batch)
    specs = {"tokens": P(bat, None), "labels": P(bat, None)}
    if cfg.family == "vlm":
        specs["patch_embeds"] = P(bat, None, None)
    if cfg.family == "encdec":
        specs["src_embeds"] = P(bat, None, None)
    if shape.kind == "prefill":
        specs.pop("labels")
    return {"batch": specs}


def _kv_spec(cfg: ModelConfig, mesh: Mesh, rules, gb: int, *, stacked=True,
             kv_alt: bool = False):
    """Spec for a [L?, B, Hkv, S, D] KV cache leaf."""
    tp = _ax(rules, "tp")
    n_tp = _mesh_size(mesh, tp)
    bat = batch_axis(mesh, rules, gb)
    if cfg.n_kv_heads % n_tp == 0:
        h_ax, s_ax = tp, None
    elif kv_alt and gb % n_tp == 0:
        # alt layout (§Perf): batch over the TP axis, sequence over data —
        # the kv_len scatter stays shard-local (no cache resharding)
        data = _ax(rules, "seqs")
        body = (tp, None, data, None)
        return P(None, *body) if stacked else P(*body)
    else:
        h_ax, s_ax = None, tp
    if gb == 1:  # long-context: spread the sequence as widely as possible
        bat = None
        s_parts = []
        data = _ax(rules, "seqs")
        if data is not None:
            s_parts.extend(data if isinstance(data, tuple) else (data,))
        if h_ax is None and tp is not None:
            s_parts.extend(tp if isinstance(tp, tuple) else (tp,))
        s_ax = (tuple(s_parts) if len(s_parts) > 1
                else (s_parts[0] if s_parts else None))
    body = (bat, h_ax, s_ax, None)
    return P(None, *body) if stacked else P(*body)


def _mla_spec(cfg, mesh, rules, gb):
    """Spec for MLA latent caches [L, B, S, r]: sequence over 'model'."""
    tp = _ax(rules, "tp")
    bat = batch_axis(mesh, rules, gb) if gb > 1 else None
    s_ax = tp
    if gb == 1:
        data = _ax(rules, "seqs")
        parts = list(data if isinstance(data, tuple) else (data,)) + \
            list(tp if isinstance(tp, tuple) else (tp,))
        s_ax = tuple(parts)
    return P(None, bat, s_ax, None)


def cache_specs(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh,
                kv_alt: bool = False) -> Any:
    rules = make_rules(mesh)
    gb = shape.global_batch
    bat = batch_axis(mesh, rules, gb)
    tp = _ax(rules, "tp")
    n_tp = _mesh_size(mesh, tp)
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        if cfg.mla is not None:
            one = lambda: (_mla_spec(cfg, mesh, rules, gb),
                           _mla_spec(cfg, mesh, rules, gb))
        else:
            one = lambda: (_kv_spec(cfg, mesh, rules, gb, kv_alt=kv_alt),
                           _kv_spec(cfg, mesh, rules, gb, kv_alt=kv_alt))
        n_groups = 2 if (cfg.moe and cfg.moe.first_k_dense) else 1
        return [one() for _ in range(n_groups)]

    if fam == "encdec":
        kv = lambda: (_kv_spec(cfg, mesh, rules, gb, kv_alt=kv_alt),
                      _kv_spec(cfg, mesh, rules, gb, kv_alt=kv_alt))
        return (kv(), kv())

    if fam == "hybrid":
        s = cfg.ssm
        di = s.expand * cfg.d_model
        h = di // s.head_dim
        conv_c = di + 2 * s.n_groups * s.d_state
        conv_ax = tp if conv_c % n_tp == 0 else None
        h_ax = tp if h % n_tp == 0 else None
        return {"mamba": {"conv": P(None, bat, None, conv_ax),
                          "ssm": P(None, bat, h_ax, None, None)},
                "attn_kv": (_kv_spec(cfg, mesh, rules, gb),
                            _kv_spec(cfg, mesh, rules, gb))}

    if fam == "ssm_xlstm":
        # tiny states: batch over data, inner dims over model when divisible
        di = 2 * cfg.d_model
        dh = di // cfg.n_heads
        dk_ax = tp if dh % n_tp == 0 else None
        out = []
        for ch in (cfg.xlstm_pattern or "ms" * (cfg.n_layers // 2)):
            if ch == "m":
                out.append({"conv": P(bat, None, None),
                            "mlstm": _mlstm_spec(bat, dk_ax)})
            else:
                out.append({"slstm": _slstm_spec(bat)})
        return out
    raise ValueError(fam)


def _mlstm_spec(bat, dk_ax):
    from repro.models.ssm import MLSTMState
    return MLSTMState(C=P(bat, None, dk_ax, None), n=P(bat, None, dk_ax),
                      m=P(bat, None))


def _slstm_spec(bat):
    from repro.models.ssm import SLSTMState
    return SLSTMState(c=P(bat, None, None), n=P(bat, None, None),
                      h=P(bat, None, None), m=P(bat, None, None))


def decode_input_specs(cfg: ModelConfig, shape: ShapeCfg, mesh: Mesh,
                       kv_alt: bool = False) -> Any:
    rules = make_rules(mesh)
    bat = batch_axis(mesh, rules, shape.global_batch)
    return {"cache": cache_specs(cfg, shape, mesh, kv_alt=kv_alt),
            "tokens": P(bat, None),
            "kv_len": P(bat)}


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
