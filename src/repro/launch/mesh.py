"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod = 16x16 = 256 chips ('data','model'); multi-pod = 2 pods
x 256 = 512 chips with the leading 'pod' axis crossing the DCI."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run launcher must set XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for in-process sharding tests (8 forced host devices)."""
    import numpy as np
    n = 1
    for s in shape:
        n *= s
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
