import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks device count on first init.
# This flag lives ONLY here — smoke tests and benches see the real device.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with ShapeDtypeStruct inputs (no allocation), and extract the
per-device memory analysis, FLOP/byte cost analysis, and collective byte
counts that feed EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out artifacts/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, applicable
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as SP
from repro.models.registry import ARCH_IDS, build, get_config, input_specs
from repro.optim import make_optimizer
from repro.sharding import param_specs, use_mesh
from repro.train.train_step import make_train_step

# TPU v5e-class hardware constants (EXPERIMENTS.md §Roofline)
HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}

# HLO text: `%name = f32[8,128]{1,0} all-reduce(...)` or tuple-shaped results
_COLL_RE = re.compile(
    r"=\s*\(?((?:\w+\[[\d,]*\](?:\{[^}]*\})?(?:,\s*)?)+)\)?\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective in the (SPMD-partitioned,
    per-device) HLO."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2).lower()
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dtype, dims = sm.group(1), sm.group(2)
            b = _DTYPE_BYTES.get(dtype, 4)
            for d in dims.split(","):
                if d:
                    b *= int(d)
            nbytes += b
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    return out


def _abs_key():
    return jax.random.PRNGKey(0)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               fsdp_over_pod: bool = False, block_k: int | None = None,
               seq_parallel: bool = False, remat: str | None = None,
               microbatch: int | None = None, serve_replicated: bool = False,
               kv_alt: bool = False):
    import dataclasses
    cfg = get_config(arch)
    if seq_parallel or remat or microbatch:
        cfg = dataclasses.replace(
            cfg, seq_parallel=seq_parallel or cfg.seq_parallel,
            remat_policy=remat or cfg.remat_policy,
            microbatch=microbatch or cfg.microbatch)
    shape = SHAPES[shape_name]
    if not applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(DESIGN.md §4)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(cfg)
    t0 = time.time()
    from repro.sharding import make_rules
    rules = make_rules(mesh, fsdp_over_pod=fsdp_over_pod)
    with use_mesh(mesh, rules):
        params_abs = jax.eval_shape(model.init, _abs_key())
        p_specs = param_specs(params_abs)
        if serve_replicated and shape.kind != "train":
            from repro.sharding import drop_axes
            p_specs = drop_axes(p_specs, axes=("data", "pod"))
        p_sh = SP.to_shardings(p_specs, mesh)
        kw = {}
        if block_k:
            kw["block_k"] = block_k
        ins = input_specs(cfg, shape)
        if shape.kind == "train":
            opt_init, opt_update = make_optimizer(cfg.optimizer)
            n_micro = (shape.global_batch // cfg.microbatch
                       if cfg.microbatch else None)
            step = make_train_step(model, opt_init, opt_update, n_micro)
            opt_abs = jax.eval_shape(opt_init, params_abs)
            o_sh = SP.to_shardings(param_specs(opt_abs), mesh)
            b_sh = SP.to_shardings(SP.batch_specs(cfg, shape, mesh), mesh)
            jf = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh["batch"]),
                         out_shardings=(p_sh, o_sh, None))
            lowered = jf.lower(params_abs, opt_abs, ins["batch"])
        elif shape.kind == "prefill":
            b_sh = SP.to_shardings(SP.batch_specs(cfg, shape, mesh), mesh)
            jf = jax.jit(lambda p, b: model.prefill(p, b, **kw),
                         in_shardings=(p_sh, b_sh["batch"]))
            lowered = jf.lower(params_abs, ins["batch"])
        else:  # decode
            d_sh = SP.to_shardings(
                SP.decode_input_specs(cfg, shape, mesh, kv_alt=kv_alt), mesh)
            jf = jax.jit(
                lambda p, c, t, kl: model.decode_step(p, c, t, kl, **kw),
                in_shardings=(p_sh, d_sh["cache"], d_sh["tokens"],
                              d_sh["kv_len"]),
                out_shardings=(None, d_sh["cache"]))
            lowered = jf.lower(params_abs, ins["cache"], ins["tokens"],
                               ins["kv_len"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        rec = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "status": "ok", "n_devices": mesh.devices.size,
               "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1)}
        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(mem, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}
        try:
            cost = compiled.cost_analysis()
            rec["cost"] = {k: float(v) for k, v in cost.items()
                           if isinstance(v, (int, float))
                           and k in ("flops", "bytes accessed",
                                     "transcendentals", "optimal_seconds")}
        except Exception as e:  # pragma: no cover
            rec["cost"] = {"error": str(e)}
        try:
            rec["collectives"] = collective_bytes(compiled.as_text())
        except Exception as e:  # pragma: no cover
            rec["collectives"] = {"error": str(e)}
        # analytic model FLOPs for §Roofline's usefulness ratio
        n_active = cfg.active_param_count()
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        mult = 6 if shape.kind == "train" else 2
        rec["model_flops"] = float(mult * n_active * tokens)
        rec["param_count"] = cfg.param_count()
        rec["active_param_count"] = n_active
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--fsdp-over-pod", action="store_true")
    ap.add_argument("--block-k", type=int, default=None)
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--remat", default=None, choices=[None, "full", "dots"])
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--serve-replicated", action="store_true")
    ap.add_argument("--kv-alt", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    results = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                name = (f"{arch}_{shape}_{'multi' if multi else 'single'}"
                        f"{args.tag}")
                path = os.path.join(args.out, name + ".json")
                if os.path.exists(path):
                    print(f"[cached] {name}")
                    results.append(json.load(open(path)))
                    continue
                print(f"[dryrun] {name} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape, multi,
                                     fsdp_over_pod=args.fsdp_over_pod,
                                     block_k=args.block_k,
                                     seq_parallel=args.seq_parallel,
                                     remat=args.remat,
                                     microbatch=args.microbatch,
                                     serve_replicated=args.serve_replicated,
                                     kv_alt=args.kv_alt)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": str(e),
                           "trace": traceback.format_exc()[-2000:]}
                json.dump(rec, open(path, "w"), indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    mem = rec.get("memory", {})
                    tb = mem.get("temp_size_in_bytes")
                    extra = (f" compile={rec['compile_s']}s"
                             f" temp={tb/2**30:.2f}GiB" if tb else "")
                print(f"[{status}] {name}{extra}", flush=True)
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nTOTAL ok={n_ok} skipped={n_skip} error={n_err}")
    if n_err:
        for r in results:
            if r["status"] == "error":
                print(f"  ERROR {r['arch']} {r['shape']} {r['mesh']}: "
                      f"{r['error'][:200]}")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
