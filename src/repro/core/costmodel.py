"""Cost model calibrated to the paper's Table 1 (gem5-APU configuration).

The paper evaluates on a cycle-accurate simulator; this framework replaces
it with a deterministic analytic cost model attached to the functional
protocol.  Latencies come straight from Table 1:

  L1 data cache: 4-cycle latency, 16-entry sFIFO, 64B blocks
  L2 cache:     24-cycle latency, 24-entry sFIFO
  DRAM:         DDR3 8-channel 500 MHz  -> ~150 core cycles modeled
  protocol:     no-allocate, write-combining

Charging rules (DESIGN.md §2 "cost model honesty"):
  * every op charges cycles to the issuing cache's accumulator;
  * selective/full flush also charges the *victim* cache (its L1 is busy)
    and the issuer waits for completion (paper §4.2 step 4 feedback);
  * `l2_accesses` counts data-carrying L2 transactions (fills, block
    writebacks, L2 atomics) — the bandwidth proxy used by Fig. 5;
  * probes / NACKs are control messages, counted separately.

Makespan of a run = max over caches of per-cache cycles.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CostParams:
    # Table 1 latencies (cycles)
    l1_lat: float = 4.0
    l2_lat: float = 24.0
    dram_lat: float = 150.0
    # throughput terms
    wb_per_block: float = 4.0      # pipelined writeback issue per 64B block
    inv_flash: float = 1.0         # single-cycle flash invalidate (§2.2)
    probe_lat: float = 8.0         # selective-flush / inv probe hop
    tbl_lat: float = 1.0           # LR/PA CAM lookup
    # work model for the work-stealing apps (cycles)
    task_base: float = 20.0
    per_edge: float = 6.0


class Counters(NamedTuple):
    cycles: jnp.ndarray        # [n_caches] f32 per-cache busy cycles
    l2_accesses: jnp.ndarray   # [] f32 data transactions at L2 (Fig. 5 metric)
    wb_blocks: jnp.ndarray     # [] f32 blocks written back (flush traffic)
    inv_full: jnp.ndarray      # [] f32 whole-cache invalidations
    inv_per_cache: jnp.ndarray # [n_caches] f32 invalidations per cache (cold-miss model)
    probes: jnp.ndarray        # [] f32 control probes sent
    promotions: jnp.ndarray    # [] f32 promoted local acquires (PA-TBL hits)
    local_syncs: jnp.ndarray   # [] f32
    remote_syncs: jnp.ndarray  # [] f32
    global_syncs: jnp.ndarray  # [] f32
    l1_hits: jnp.ndarray       # [] f32
    l1_misses: jnp.ndarray     # [] f32
    steals: jnp.ndarray        # [] f32
    recoveries: jnp.ndarray    # [] f32 crash-recovery drains (lease expiry)


def make_counters(n_caches: int) -> Counters:
    # one distinct zero buffer per scalar: a Counters pytree is donated
    # through the scheduler jit boundary (harness.py), and XLA rejects
    # donating the same buffer twice — a shared 0.0 constant would be.
    zs = jnp.zeros((12,), jnp.float32)
    (l2_accesses, wb_blocks, inv_full, probes, promotions, local_syncs,
     remote_syncs, global_syncs, l1_hits, l1_misses, steals, recoveries) = \
        (zs[i] for i in range(12))
    return Counters(cycles=jnp.zeros((n_caches,), jnp.float32),
                    l2_accesses=l2_accesses, wb_blocks=wb_blocks,
                    inv_full=inv_full,
                    inv_per_cache=jnp.zeros((n_caches,), jnp.float32),
                    probes=probes, promotions=promotions,
                    local_syncs=local_syncs, remote_syncs=remote_syncs,
                    global_syncs=global_syncs, l1_hits=l1_hits,
                    l1_misses=l1_misses, steals=steals,
                    recoveries=recoveries)


def charge(c: Counters, cid, cyc) -> Counters:
    return c._replace(cycles=c.cycles.at[cid].add(jnp.float32(cyc)))


def charge_all(c: Counters, cyc) -> Counters:
    return c._replace(cycles=c.cycles + jnp.float32(cyc))


def bump(c: Counters, **kw) -> Counters:
    return c._replace(**{k: getattr(c, k) + jnp.float32(v) for k, v in kw.items()})


def makespan(c: Counters) -> jnp.ndarray:
    return jnp.max(c.cycles)


def charged_since(c: Counters, clock0) -> jnp.ndarray:
    """Per-cache cycles charged since a captured clock vector — the
    attribution primitive the event tracer and the per-turn latency
    histograms use (DESIGN.md §11).  Charges land on the lane they
    were billed to, so a lane's delta across an op includes NACK/flush
    time OTHER lanes' ops billed it in the same call — by design: the
    trace answers "where did this agent's cycles go", not "who issued"."""
    return c.cycles - jnp.asarray(clock0, jnp.float32)
