"""Synchronization scopes (OpenCL-style, paper §2.1) and their mapping onto
the TPU multi-pod mesh used by the framework layer (DESIGN.md §2).

GPU scope            framework scope          mesh realization
-----------------    ---------------------    -------------------------------
wi / wv (work-item)  core-local               inside one Pallas program
wg  ("local")        chip-local               HBM, no collective
cmp ("global")       pod scope                ICI collectives ('data','model')
sys                  cross-pod scope          DCI collectives ('pod')
"""
from __future__ import annotations

import enum


class Scope(enum.IntEnum):
    WI = 0    # work-item
    WV = 1    # SIMD-group (wavefront)
    WG = 2    # work-group  — "local"  (L1 / chip)
    CMP = 3   # device      — "global" (L2 / pod)
    SYS = 4   # system      —          (main memory / cross-pod)


# Mesh axes a collective at each scope spans, for the framework layer.
SCOPE_AXES = {
    Scope.WG: (),                        # chip-local: no collective
    Scope.CMP: ("data", "model"),        # within-pod ICI
    Scope.SYS: ("pod", "data", "model"), # cross-pod DCI + ICI
}


def axes_for(scope: Scope, mesh_axis_names: tuple[str, ...]) -> tuple[str, ...]:
    """Axes (present in the mesh) that a collective at `scope` spans."""
    want = SCOPE_AXES[scope]
    return tuple(a for a in want if a in mesh_axis_names)
