"""Scope-parametric synchronization ISA — one masked op surface.

The paper's interface (§2.1) is an ISA of scoped atomics:
`atomic_CAS_acq_wg`, `atomic_ST_rem_rel_cmp`, … — scope is an *operand*
of the instruction, not a property of the caller.  This module is that
surface for the simulated machine: four masked multi-agent entry points

    acquire(proto, cfg, st, active, addrs, expect, new, scope=LOCAL)
    release(proto, cfg, st, active, addrs, vals,        scope=LOCAL)
    load(cfg, st, active, addrs,                        scope=LOCAL)
    store(cfg, st, active, addrs, vals,                 scope=LOCAL)

where `active` is an [n_caches] participation mask and `scope` is either
a static Python int or a per-agent {LOCAL, REMOTE, GLOBAL} int array —
one call can carry a mixed-scope bundle, e.g. owners acquiring at LOCAL
scope while a thief acquires at REMOTE scope in the same instruction.

Dispatch (DESIGN.md §9) goes into the *protocol's* per-scope op table —
the scenario mapping (baseline realizes LOCAL as global sync, scope_only
realizes REMOTE as unsafe local sync) lives entirely in the registered
`Protocol` object, never in workload code.  REMOTE-scope lanes use the
protocol's batched address-disjoint remote twin when it declares one
(`Protocol.remote_batchable`); otherwise they fall back to the scalar
serializing op, which supports at most ONE active remote lane per call —
the harness never co-schedules remote turns without the capability.

Data ops (`load`/`store`) accept `scope` for ISA uniformity but are
scope-invariant in this memory model: ordinary accesses always route
through the issuing agent's L1 (write-combining, no-allocate) and the
scope of the *synchronization* ops alone decides when that data becomes
visible remotely.  That asymmetry is the paper's point.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core import protocol as P

# Scope codes of the ISA.  LOCAL is wg ("local") scope, and both REMOTE
# and GLOBAL are realizations of cmp ("global") scope visibility
# (core/scopes.py): GLOBAL pays the full flush/invalidate on every op,
# REMOTE is the paper's promoted flavor — cheap until a remote sharer
# actually appears.  They are distinct ISA operands because protocols
# translate them differently.
LOCAL = 0    # own-L1 synchronization (atomic_*_wg)
REMOTE = 1   # promoted cross-agent synchronization (atomic_*_rem_cmp)
GLOBAL = 2   # heavyweight everyone-pays synchronization (atomic_*_cmp)

SCOPES = (LOCAL, REMOTE, GLOBAL)
SCOPE_NAMES = {LOCAL: "loc", REMOTE: "rem", GLOBAL: "glob"}


def _check_static(scope: int) -> None:
    if scope not in SCOPE_NAMES:
        raise ValueError(f"unknown scope {scope!r}; "
                         f"valid: {sorted(SCOPE_NAMES)} "
                         f"(ops.LOCAL / ops.REMOTE / ops.GLOBAL)")


def _bcast(x, n: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.asarray(x, jnp.int32), (n,))


def _gate_crashed(proto: P.Protocol, st: P.Store, active):
    """Crash-fault lane kill (Protocol.crash_gate): once the victim's
    clock passes the crash time, its *release* instructions never execute
    — including their lease clears, so the lease taken at acquire
    survives for the recovery drain to act on.  Acquires stay live: the
    dying agent keeps entering critical sections it can never exit, which
    is exactly the die-holding-lock state.  Static no-op when the
    protocol is healthy."""
    if proto.crash_gate is None:
        return active
    victim, at = proto.crash_gate
    n = st.counters.cycles.shape[0]
    dying = (jnp.arange(n, dtype=jnp.int32) == victim) \
        & (st.counters.cycles >= jnp.float32(at))
    return jnp.asarray(active, bool) & ~dying


def _acquire_rem(proto: P.Protocol, cfg, st, rem, addrs, expect, new):
    """REMOTE-scope acquire lanes: batched twin when the protocol declares
    one, else the scalar serializing op (at most one active lane)."""
    if proto.acquire_rem_b is not None:
        return proto.acquire_rem_b(cfg, st, rem, addrs, expect, new)
    n = cfg.n_caches
    rem = jnp.asarray(rem, bool)
    addrs32, expect, new = (_bcast(a, n) for a in (addrs, expect, new))
    cid = jnp.argmax(rem).astype(jnp.int32)

    def do(s):
        return proto.acquire_rem(cfg, s, cid, addrs32[cid], expect[cid],
                                 new[cid])

    def skip(s):
        return s, jnp.int32(0)

    st, old_c = lax.cond(jnp.any(rem), do, skip, st)
    lanes = jnp.arange(n, dtype=jnp.int32)
    return st, jnp.where(lanes == cid, old_c, jnp.int32(0))


def _release_rem(proto: P.Protocol, cfg, st, rem, addrs, vals):
    if proto.release_rem_b is not None:
        return proto.release_rem_b(cfg, st, rem, addrs, vals)
    n = cfg.n_caches
    rem = jnp.asarray(rem, bool)
    addrs32, vals = (_bcast(a, n) for a in (addrs, vals))
    cid = jnp.argmax(rem).astype(jnp.int32)
    return lax.cond(
        jnp.any(rem),
        lambda s: proto.release_rem(cfg, s, cid, addrs32[cid], vals[cid]),
        lambda s: s, st)


def acquire(proto: P.Protocol, cfg: P.ProtoConfig, st: P.Store, active,
            addrs, expect, new, scope=LOCAL):
    """Scoped acquire, one per active agent: CAS(expect -> new) on
    `addrs[i]` at `scope[i]` for every active lane i, through `proto`'s
    translation of that scope.  Returns (store', old [n_caches]);
    inactive lanes' old values are unspecified.

    A static int `scope` compiles to exactly the one table entry; a
    per-agent array dispatches each scope class masked (REMOTE lanes
    must be address-disjoint — the harness's obligation)."""
    addrs, expect, new = (_bcast(a, cfg.n_caches)
                          for a in (addrs, expect, new))
    if isinstance(scope, int):
        _check_static(scope)
        if scope == LOCAL:
            st, old = proto.acquire_loc_b(cfg, st, active, addrs, expect,
                                          new)
        elif scope == GLOBAL:
            st, old = proto.acquire_glob_b(cfg, st, active, addrs, expect,
                                           new)
        else:
            st, old = _acquire_rem(proto, cfg, st, active, addrs, expect,
                                   new)
        # clock-stamped lease bookkeeping (crash recovery, DESIGN.md §10):
        # pure metadata, charges nothing — zero-churn schedules unchanged
        return P.lease_stamp(st, active, addrs), old
    scope = jnp.asarray(scope, jnp.int32)
    active = jnp.asarray(active, bool)
    loc = active & (scope == LOCAL)
    rem = active & (scope == REMOTE)
    glob = active & (scope == GLOBAL)
    st, old_l = proto.acquire_loc_b(cfg, st, loc, addrs, expect, new)
    st, old_g = proto.acquire_glob_b(cfg, st, glob, addrs, expect, new)
    st, old_r = _acquire_rem(proto, cfg, st, rem, addrs, expect, new)
    old = jnp.where(rem, old_r, jnp.where(glob, old_g, old_l))
    return P.lease_stamp(st, active, addrs), old


def release(proto: P.Protocol, cfg: P.ProtoConfig, st: P.Store, active,
            addrs, vals, scope=LOCAL):
    """Scoped release, one per active agent: store `vals[i]` to
    `addrs[i]` with release semantics at `scope[i]`.  Returns store'."""
    addrs, vals = (_bcast(a, cfg.n_caches) for a in (addrs, vals))
    active = _gate_crashed(proto, st, active)
    if isinstance(scope, int):
        _check_static(scope)
        if scope == LOCAL:
            st = proto.release_loc_b(cfg, st, active, addrs, vals)
        elif scope == GLOBAL:
            st = proto.release_glob_b(cfg, st, active, addrs, vals)
        else:
            st = _release_rem(proto, cfg, st, active, addrs, vals)
        # lease bookkeeping mirror of `acquire` (pure metadata)
        return P.lease_clear(st, active)
    scope = jnp.asarray(scope, jnp.int32)
    active = jnp.asarray(active, bool)
    st = proto.release_loc_b(cfg, st, active & (scope == LOCAL), addrs, vals)
    st = proto.release_glob_b(cfg, st, active & (scope == GLOBAL), addrs,
                              vals)
    st = _release_rem(proto, cfg, st, active & (scope == REMOTE), addrs,
                      vals)
    return P.lease_clear(st, active)


def load(cfg: P.ProtoConfig, st: P.Store, active, addrs, scope=LOCAL):
    """Ordinary scoped read, one per active agent (scope-invariant: data
    always routes through the issuing agent's L1 — module docstring)."""
    if isinstance(scope, int):
        _check_static(scope)
    return P.b_load(cfg, st, active, addrs)


def store(cfg: P.ProtoConfig, st: P.Store, active, addrs, vals,
          scope=LOCAL, *, force_tail=False):
    """Ordinary scoped write, one per active agent (scope-invariant)."""
    if isinstance(scope, int):
        _check_static(scope)
    return P.b_store_word(cfg, st, active, addrs, vals, force_tail)
