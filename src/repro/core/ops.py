"""Scope-parametric synchronization ISA — one masked op surface.

The paper's interface (§2.1) is an ISA of scoped atomics:
`atomic_CAS_acq_wg`, `atomic_ST_rem_rel_cmp`, … — scope is an *operand*
of the instruction, not a property of the caller.  This module is that
surface for the simulated machine: four masked multi-agent entry points

    acquire(proto, cfg, st, active, addrs, expect, new, scope=LOCAL)
    release(proto, cfg, st, active, addrs, vals,        scope=LOCAL)
    load(cfg, st, active, addrs,                        scope=LOCAL)
    store(cfg, st, active, addrs, vals,                 scope=LOCAL)

where `active` is an [n_caches] participation mask and `scope` is either
a static Python int or a per-agent {LOCAL, REMOTE, GLOBAL} int array —
one call can carry a mixed-scope bundle, e.g. owners acquiring at LOCAL
scope while a thief acquires at REMOTE scope in the same instruction.

Dispatch (DESIGN.md §9) goes into the *protocol's* per-scope op table —
the scenario mapping (baseline realizes LOCAL as global sync, scope_only
realizes REMOTE as unsafe local sync) lives entirely in the registered
`Protocol` object, never in workload code.  REMOTE-scope lanes use the
protocol's batched address-disjoint remote twin when it declares one
(`Protocol.remote_batchable`); otherwise they fall back to the scalar
serializing op, which supports at most ONE active remote lane per call —
the harness never co-schedules remote turns without the capability.

Data ops (`load`/`store`) accept `scope` for ISA uniformity but are
scope-invariant in this memory model: ordinary accesses always route
through the issuing agent's L1 (write-combining, no-allocate) and the
scope of the *synchronization* ops alone decides when that data becomes
visible remotely.  That asymmetry is the paper's point.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import protocol as P
from repro.core import tables
from repro.obs import trace as T

# Scope codes of the ISA.  LOCAL is wg ("local") scope, and both REMOTE
# and GLOBAL are realizations of cmp ("global") scope visibility
# (core/scopes.py): GLOBAL pays the full flush/invalidate on every op,
# REMOTE is the paper's promoted flavor — cheap until a remote sharer
# actually appears.  They are distinct ISA operands because protocols
# translate them differently.
LOCAL = 0    # own-L1 synchronization (atomic_*_wg)
REMOTE = 1   # promoted cross-agent synchronization (atomic_*_rem_cmp)
GLOBAL = 2   # heavyweight everyone-pays synchronization (atomic_*_cmp)

SCOPES = (LOCAL, REMOTE, GLOBAL)
SCOPE_NAMES = {LOCAL: "loc", REMOTE: "rem", GLOBAL: "glob"}


def _check_static(scope: int) -> None:
    if scope not in SCOPE_NAMES:
        raise ValueError(f"unknown scope {scope!r}; "
                         f"valid: {sorted(SCOPE_NAMES)} "
                         f"(ops.LOCAL / ops.REMOTE / ops.GLOBAL)")


def _bcast(x, n: int) -> jnp.ndarray:
    return jnp.broadcast_to(jnp.asarray(x, jnp.int32), (n,))


def _scope_label(scope) -> str:
    # unknown static ints still reach _check_static's ValueError below
    return SCOPE_NAMES.get(scope, "invalid") if isinstance(scope, int) \
        else "mixed"


def _acquire_outcome(cfg, st: P.Store, addrs, scope):
    """Pre-dispatch trace outcome per lane (only traced when tracing is
    on): LOCAL lanes promote iff their PA-TBL holds the address, REMOTE
    lanes probe iff any OTHER cache's LR-TBL records it (else the probe
    round all-NACKs), GLOBAL lanes always pay the full invalidate."""
    n = cfg.n_caches
    promote = jax.vmap(tables.pa_contains)(st.pa, addrs)
    ptrs = jax.vmap(lambda t: jax.vmap(
        lambda a: tables.lr_lookup(t, a))(addrs))(st.lr)   # [cache, lane]
    others = jnp.arange(n)[:, None] != jnp.arange(n)[None, :]
    sharer = jnp.any((ptrs >= 0) & others, axis=0)
    scope_arr = jnp.broadcast_to(jnp.asarray(scope, jnp.int32), (n,))
    loc = jnp.where(promote, T.OC_PROMOTE, T.OC_HIT)
    rem = jnp.where(sharer, T.OC_PROBE, T.OC_NACK)
    return jnp.where(scope_arr == LOCAL, loc,
                     jnp.where(scope_arr == REMOTE, rem, T.OC_GLOBAL))


def _release_outcome(cfg, scope):
    scope_arr = jnp.broadcast_to(jnp.asarray(scope, jnp.int32),
                                 (cfg.n_caches,))
    return jnp.where(scope_arr == LOCAL, T.OC_HIT,
                     jnp.where(scope_arr == REMOTE, T.OC_PROBE,
                               T.OC_GLOBAL))


def _gate_crashed(proto: P.Protocol, st: P.Store, active):
    """Crash-fault lane kill (Protocol.crash_gate): once the victim's
    clock passes the crash time, its *release* instructions never execute
    — including their lease clears, so the lease taken at acquire
    survives for the recovery drain to act on.  Acquires stay live: the
    dying agent keeps entering critical sections it can never exit, which
    is exactly the die-holding-lock state.  Static no-op when the
    protocol is healthy."""
    if proto.crash_gate is None:
        return active
    victim, at = proto.crash_gate
    n = st.counters.cycles.shape[0]
    dying = (jnp.arange(n, dtype=jnp.int32) == victim) \
        & (st.counters.cycles >= jnp.float32(at))
    return jnp.asarray(active, bool) & ~dying


def _acquire_rem(proto: P.Protocol, cfg, st, rem, addrs, expect, new):
    """REMOTE-scope acquire lanes: batched twin when the protocol declares
    one, else the scalar serializing op (at most one active lane)."""
    if proto.acquire_rem_b is not None:
        return proto.acquire_rem_b(cfg, st, rem, addrs, expect, new)
    n = cfg.n_caches
    rem = jnp.asarray(rem, bool)
    addrs32, expect, new = (_bcast(a, n) for a in (addrs, expect, new))
    cid = jnp.argmax(rem).astype(jnp.int32)

    def do(s):
        return proto.acquire_rem(cfg, s, cid, addrs32[cid], expect[cid],
                                 new[cid])

    def skip(s):
        return s, jnp.int32(0)

    st, old_c = lax.cond(jnp.any(rem), do, skip, st)
    lanes = jnp.arange(n, dtype=jnp.int32)
    return st, jnp.where(lanes == cid, old_c, jnp.int32(0))


def _release_rem(proto: P.Protocol, cfg, st, rem, addrs, vals):
    if proto.release_rem_b is not None:
        return proto.release_rem_b(cfg, st, rem, addrs, vals)
    n = cfg.n_caches
    rem = jnp.asarray(rem, bool)
    addrs32, vals = (_bcast(a, n) for a in (addrs, vals))
    cid = jnp.argmax(rem).astype(jnp.int32)
    return lax.cond(
        jnp.any(rem),
        lambda s: proto.release_rem(cfg, s, cid, addrs32[cid], vals[cid]),
        lambda s: s, st)


def acquire(proto: P.Protocol, cfg: P.ProtoConfig, st: P.Store, active,
            addrs, expect, new, scope=LOCAL):
    """Scoped acquire, one per active agent: CAS(expect -> new) on
    `addrs[i]` at `scope[i]` for every active lane i, through `proto`'s
    translation of that scope.  Returns (store', old [n_caches]);
    inactive lanes' old values are unspecified.

    A static int `scope` compiles to exactly the one table entry; a
    per-agent array dispatches each scope class masked (REMOTE lanes
    must be address-disjoint — the harness's obligation)."""
    addrs, expect, new = (_bcast(a, cfg.n_caches)
                          for a in (addrs, expect, new))
    traced = T.enabled(st.trace)
    if traced:
        clock0 = st.counters.cycles
        outcome = _acquire_outcome(cfg, st, addrs, scope)
    with jax.named_scope(f"ops.acquire.{_scope_label(scope)}"):
        if isinstance(scope, int):
            _check_static(scope)
            if scope == LOCAL:
                st, old = proto.acquire_loc_b(cfg, st, active, addrs,
                                              expect, new)
            elif scope == GLOBAL:
                st, old = proto.acquire_glob_b(cfg, st, active, addrs,
                                               expect, new)
            else:
                st, old = _acquire_rem(proto, cfg, st, active, addrs,
                                       expect, new)
        else:
            scope_a = jnp.asarray(scope, jnp.int32)
            active = jnp.asarray(active, bool)
            loc = active & (scope_a == LOCAL)
            rem = active & (scope_a == REMOTE)
            glob = active & (scope_a == GLOBAL)
            st, old_l = proto.acquire_loc_b(cfg, st, loc, addrs, expect,
                                            new)
            st, old_g = proto.acquire_glob_b(cfg, st, glob, addrs, expect,
                                             new)
            st, old_r = _acquire_rem(proto, cfg, st, rem, addrs, expect,
                                     new)
            old = jnp.where(rem, old_r, jnp.where(glob, old_g, old_l))
    # clock-stamped lease bookkeeping (crash recovery, DESIGN.md §10):
    # pure metadata, charges nothing — zero-churn schedules unchanged
    st = P.lease_stamp(st, active, addrs)
    if traced:
        st = T.record_op(st, active, T.ACQUIRE, scope, addrs, clock0,
                         outcome)
    return st, old


def release(proto: P.Protocol, cfg: P.ProtoConfig, st: P.Store, active,
            addrs, vals, scope=LOCAL):
    """Scoped release, one per active agent: store `vals[i]` to
    `addrs[i]` with release semantics at `scope[i]`.  Returns store'."""
    addrs, vals = (_bcast(a, cfg.n_caches) for a in (addrs, vals))
    active = _gate_crashed(proto, st, active)
    traced = T.enabled(st.trace)
    if traced:
        clock0 = st.counters.cycles
    with jax.named_scope(f"ops.release.{_scope_label(scope)}"):
        if isinstance(scope, int):
            _check_static(scope)
            if scope == LOCAL:
                st = proto.release_loc_b(cfg, st, active, addrs, vals)
            elif scope == GLOBAL:
                st = proto.release_glob_b(cfg, st, active, addrs, vals)
            else:
                st = _release_rem(proto, cfg, st, active, addrs, vals)
        else:
            scope_a = jnp.asarray(scope, jnp.int32)
            active = jnp.asarray(active, bool)
            st = proto.release_loc_b(cfg, st, active & (scope_a == LOCAL),
                                     addrs, vals)
            st = proto.release_glob_b(cfg, st, active & (scope_a == GLOBAL),
                                      addrs, vals)
            st = _release_rem(proto, cfg, st, active & (scope_a == REMOTE),
                              addrs, vals)
    # lease bookkeeping mirror of `acquire` (pure metadata)
    st = P.lease_clear(st, active)
    if traced:
        st = T.record_op(st, active, T.RELEASE, scope, addrs, clock0,
                         _release_outcome(cfg, scope))
    return st


def _l1_state(cfg, st, addrs, plane):
    """Pre-op L1 metadata bit per lane at `addrs` (trace classification)."""
    b, o = P._split(cfg, _bcast(addrs, cfg.n_caches))
    return P._pl_get(plane, jnp.arange(cfg.n_caches), b, o)


def load(cfg: P.ProtoConfig, st: P.Store, active, addrs, scope=LOCAL):
    """Ordinary scoped read, one per active agent (scope-invariant: data
    always routes through the issuing agent's L1 — module docstring)."""
    if isinstance(scope, int):
        _check_static(scope)
    traced = T.enabled(st.trace)
    if traced:
        clock0 = st.counters.cycles
        hit = _l1_state(cfg, st, addrs, st.wvalid)
    st, val = P.b_load(cfg, st, active, addrs)
    if traced:
        st = T.record_op(st, active, T.LOAD, scope, addrs, clock0,
                         jnp.where(hit, T.OC_HIT, T.OC_MISS))
    return st, val


def store(cfg: P.ProtoConfig, st: P.Store, active, addrs, vals,
          scope=LOCAL, *, force_tail=False):
    """Ordinary scoped write, one per active agent (scope-invariant)."""
    if isinstance(scope, int):
        _check_static(scope)
    traced = T.enabled(st.trace)
    if traced:
        clock0 = st.counters.cycles
        # write-combining: a "hit" merges into an already-dirty word
        combined = _l1_state(cfg, st, addrs, st.wdirty)
    st, pos = P.b_store_word(cfg, st, active, addrs, vals, force_tail)
    if traced:
        st = T.record_op(st, active, T.STORE, scope, addrs, clock0,
                         jnp.where(combined, T.OC_HIT, T.OC_MISS))
    return st, pos
