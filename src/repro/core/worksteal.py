"""Compatibility shim: the work-steal simulator now lives in
`repro.workloads.worksteal`, registered as the first workload of the
pluggable asymmetric-sharing subsystem (DESIGN.md §7).  The schedulers it
used to own are the workload-agnostic `repro.workloads.harness`; counters
and solutions are bitwise-unchanged (tests/test_engine_equivalence.py).

Since the scope-parametric ISA cutover (DESIGN.md §9) the simulator
issues all synchronization through `repro.core.ops` scoped dispatch
(owner ops at LOCAL scope, steals at REMOTE scope) and resolves
protocols through the registry — the re-exported surface below is
unchanged.

Import from here for the stable public API."""
from repro.workloads.worksteal import (  # noqa: F401
    AppResult,
    ENGINES,
    QMETA,
    SCENARIOS,
    SimState,
    WSConfig,
    WorkStealSim,
    build_workload,
    reference_solution,
    run_app,
)
