"""sFIFO — the QuickRelease-style synchronization FIFO (paper §2.2, [7]).

The hardware sFIFO tracks dirty cache-block addresses in write order; a
cache flush drains a *prefix* of the FIFO instead of walking the cache.
sRSP's LR-TBL stores a pointer into this FIFO so a remote acquire drains
exactly the prefix up to the local sharer's last local release.

Functional JAX model: a *seq-tagged set*.  Each live entry carries the
monotone push counter value it was (re)pushed with; FIFO order == ascending
seq.  This makes "move-to-tail" (needed for release atomics, §4.1) and
"drain up to pointer" O(capacity) vector ops on a small fixed array, with no
ring-pointer arithmetic.

Write-combining semantics (the baseline cache protocol is no-allocate,
write-combining — Table 1): a plain write to a block already in the FIFO
does not create a duplicate entry.  A *release* push forces the entry to the
tail so that draining up to its position covers every earlier write.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

INVALID = jnp.int32(-1)
_SEQ_MAX = jnp.int32(2**30)


class SFifo(NamedTuple):
    """Single-cache sFIFO.  Batch over caches by stacking a leading dim."""

    addrs: jnp.ndarray  # [cap] int32 block ids, -1 = free slot
    seqs: jnp.ndarray   # [cap] int32 push order; meaningful where addrs >= 0
    next_seq: jnp.ndarray  # [] int32 monotone counter


def make(capacity: int) -> SFifo:
    return SFifo(
        addrs=jnp.full((capacity,), INVALID, jnp.int32),
        seqs=jnp.zeros((capacity,), jnp.int32),
        next_seq=jnp.int32(0),
    )


def size(f: SFifo) -> jnp.ndarray:
    return jnp.sum(f.addrs >= 0).astype(jnp.int32)


def contains(f: SFifo, addr: jnp.ndarray) -> jnp.ndarray:
    return jnp.any((f.addrs == addr) & (f.addrs >= 0))


def push(f: SFifo, addr: jnp.ndarray, force_tail: bool | jnp.ndarray = False
         ) -> Tuple[SFifo, jnp.ndarray, jnp.ndarray]:
    """Insert `addr`.

    Returns (fifo', evicted_addr, pos):
      evicted_addr — block id evicted to make room (-1 if none); the caller
        must write that block back (capacity-eviction writeback, §2.2).
      pos — the seq tag of `addr`'s entry; a local release records this in
        the LR-TBL (§4.1).
    """
    addr = jnp.asarray(addr, jnp.int32)
    force_tail = jnp.asarray(force_tail, bool)
    valid = f.addrs >= 0
    hit = (f.addrs == addr) & valid
    present = jnp.any(hit)
    hit_idx = jnp.argmax(hit)

    free = ~valid
    any_free = jnp.any(free)
    free_idx = jnp.argmax(free)
    # FIFO eviction victim: smallest seq among live entries.
    oldest_idx = jnp.argmin(jnp.where(valid, f.seqs, _SEQ_MAX))

    slot = jnp.where(present, hit_idx, jnp.where(any_free, free_idx, oldest_idx))
    evicted = jnp.where(present | any_free, INVALID, f.addrs[slot])

    # Re-tag when: fresh insert, or present + force_tail (move-to-tail).
    retag = (~present) | force_tail
    new_seq_val = jnp.where(retag, f.next_seq, f.seqs[hit_idx])
    pos = new_seq_val

    addrs = jnp.where(retag, f.addrs.at[slot].set(addr), f.addrs)
    seqs = jnp.where(retag, f.seqs.at[slot].set(f.next_seq), f.seqs)
    next_seq = f.next_seq + retag.astype(jnp.int32)
    return SFifo(addrs, seqs, next_seq), evicted, pos


def drain_upto(f: SFifo, pos: jnp.ndarray) -> Tuple[SFifo, jnp.ndarray, jnp.ndarray]:
    """Remove every entry with seq <= pos (the selective flush, §4.2).

    Returns (fifo', drained_addrs, count).  `drained_addrs` is a fixed
    [capacity] int32 array in FIFO (seq) order, -1 padded at the end.
    """
    pos = jnp.asarray(pos, jnp.int32)
    valid = f.addrs >= 0
    sel = valid & (f.seqs <= pos)
    count = jnp.sum(sel).astype(jnp.int32)
    # Sort selected entries by seq; unselected sink to the back.
    key = jnp.where(sel, f.seqs, _SEQ_MAX)
    order = jnp.argsort(key)
    drained = jnp.where(jnp.arange(f.addrs.shape[0]) < count,
                        f.addrs[order], INVALID)
    addrs = jnp.where(sel, INVALID, f.addrs)
    return SFifo(addrs, f.seqs, f.next_seq), drained, count


def drain_all(f: SFifo) -> Tuple[SFifo, jnp.ndarray, jnp.ndarray]:
    """Full flush (cache-wide önbellek-temizleme) through the sFIFO."""
    return drain_upto(f, _SEQ_MAX)
