"""Packed word-bitmask primitives (DESIGN.md §8).

The protocol's per-word metadata planes (`wvalid`, `wdirty`) used to be
boolean arrays of shape `[..., W]` — one byte per tracked word.  At
n_wgs=256 those planes dominate the batched engine's in-loop scatter
traffic (ROADMAP).  This module packs them 32 words per `uint32` lane:

    boolean  [..., W]          1 byte / word
    packed   [..., ceil(W/32)] 1 bit  / word

Conventions (word-boundary rules, DESIGN.md §8):

  * word offset `o` lives in lane `o // 32`, bit `o % 32` (LSB-first);
  * the last lane of a row with `W % 32 != 0` is *ragged*: bits at
    offsets >= W are padding and MUST stay zero.  Every producer here
    preserves that invariant (`pack` zero-pads; set/clear only touch
    offsets < W), so `any_set`/`popcount` never need a tail mask.

Everything is pure jnp and shape-polymorphic over leading axes; the
boolean reference semantics of each op is documented inline and pinned
bitwise by the hypothesis property tests in tests/test_bitmask.py.
"""
from __future__ import annotations

import jax.numpy as jnp

LANE_BITS = 32


def n_lanes(n_bits: int) -> int:
    """Packed lanes needed for `n_bits` flags (static)."""
    return (n_bits + LANE_BITS - 1) // LANE_BITS


def zeros(shape: tuple, n_bits: int) -> jnp.ndarray:
    """All-clear packed plane: boolean `jnp.zeros(shape + (n_bits,))`."""
    return jnp.zeros(tuple(shape) + (n_lanes(n_bits),), jnp.uint32)


def word_index(o) -> jnp.ndarray:
    """Lane holding word offset `o` along the packed axis."""
    return jnp.asarray(o, jnp.int32) >> 5


def word_bit(o) -> jnp.ndarray:
    """Single-bit uint32 mask for word offset `o` within its lane."""
    return jnp.uint32(1) << (jnp.asarray(o, jnp.uint32) & jnp.uint32(31))


def test_word(words: jnp.ndarray, o) -> jnp.ndarray:
    """Boolean `flags[..., o]` given already-gathered lanes
    `words = packed[..., word_index(o)]` (the caller's gather keeps the
    protocol's fancy [lane, block] indexing out of this module)."""
    return (words & word_bit(o)) != 0


def pack(flags: jnp.ndarray) -> jnp.ndarray:
    """[..., W] bool -> [..., n_lanes(W)] uint32 (LSB-first, zero-padded)."""
    w = flags.shape[-1]
    lanes = n_lanes(w)
    pad = [(0, 0)] * (flags.ndim - 1) + [(0, lanes * LANE_BITS - w)]
    grouped = jnp.pad(flags, pad).reshape(
        flags.shape[:-1] + (lanes, LANE_BITS)).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(LANE_BITS, dtype=jnp.uint32)
    return jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)


def unpack(packed: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """[..., L] uint32 -> [..., n_bits] bool (inverse of `pack`)."""
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    bits = (packed[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * LANE_BITS,))
    return flat[..., :n_bits].astype(bool)


def get_bit(vec: jnp.ndarray, o) -> jnp.ndarray:
    """Boolean `flags[o]` of a single packed row `vec [L]`."""
    return test_word(vec[word_index(o)], o)


def set_bit(vec: jnp.ndarray, o, on=True) -> jnp.ndarray:
    """Packed row with `flags[o] |= on` (no-op where `on` is False)."""
    mask = jnp.where(jnp.asarray(on, bool), word_bit(o), jnp.uint32(0))
    return vec.at[word_index(o)].set(vec[word_index(o)] | mask)


def clear_bit(vec: jnp.ndarray, o, off=True) -> jnp.ndarray:
    """Packed row with `flags[o] &= ~off` (no-op where `off` is False)."""
    mask = jnp.where(jnp.asarray(off, bool), word_bit(o), jnp.uint32(0))
    return vec.at[word_index(o)].set(vec[word_index(o)] & ~mask)


def any_set(packed: jnp.ndarray) -> jnp.ndarray:
    """Boolean `jnp.any(flags, axis=-1)` per row."""
    return jnp.any(packed != 0, axis=-1)


def popcount_word(w: jnp.ndarray) -> jnp.ndarray:
    """Per-lane set-bit count (Hacker's Delight 5-2, branch-free)."""
    w = jnp.asarray(w, jnp.uint32)
    w = w - ((w >> 1) & jnp.uint32(0x55555555))
    w = (w & jnp.uint32(0x33333333)) + ((w >> 2) & jnp.uint32(0x33333333))
    w = (w + (w >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (w * jnp.uint32(0x01010101)) >> 24


def popcount(packed: jnp.ndarray) -> jnp.ndarray:
    """Integer `jnp.sum(flags, axis=-1)` per row (padding bits are zero
    by invariant, so no tail correction is needed)."""
    return jnp.sum(popcount_word(packed), axis=-1, dtype=jnp.int32)
