"""LR-TBL and PA-TBL — the two new hardware structures sRSP adds (paper §4).

LR-TBL (Local-Release Table): small CAM mapping
    sync-variable block address -> sFIFO position of the last local release.
A selective-flush probe consults it; only the cache holding an entry for the
probed address drains its sFIFO up to the recorded position.

PA-TBL (Promoted-Acquire Table): set of addresses whose *next* local-scope
acquire must be promoted to global scope (paper §4.3/4.4).

Overflow policies (the paper sizes the tables small and does not specify
overflow; we pick *conservative* policies that preserve the memory model —
documented in DESIGN.md §2):
  * LR-TBL eviction returns the evicted (addr, ptr) so the protocol can
    conservatively drain up to that position (no release record may be
    silently dropped).
  * PA-TBL overflow sets a sticky `promote_all` bit: every local acquire
    promotes until the next full invalidation clears the tables.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

INVALID = jnp.int32(-1)
_SEQ_MAX = jnp.int32(2**30)


class LRTbl(NamedTuple):
    addrs: jnp.ndarray  # [cap] int32, -1 free
    ptrs: jnp.ndarray   # [cap] int32 sFIFO seq positions
    ages: jnp.ndarray   # [cap] int32 insertion order (for FIFO eviction)
    next_age: jnp.ndarray  # [] int32


def lr_make(capacity: int) -> LRTbl:
    return LRTbl(
        addrs=jnp.full((capacity,), INVALID, jnp.int32),
        ptrs=jnp.zeros((capacity,), jnp.int32),
        ages=jnp.zeros((capacity,), jnp.int32),
        next_age=jnp.int32(0),
    )


def lr_insert(t: LRTbl, addr: jnp.ndarray, ptr: jnp.ndarray
              ) -> Tuple[LRTbl, jnp.ndarray, jnp.ndarray]:
    """Insert or update addr -> ptr.  Returns (tbl', evicted_addr, evicted_ptr)."""
    addr = jnp.asarray(addr, jnp.int32)
    valid = t.addrs >= 0
    hit = (t.addrs == addr) & valid
    present = jnp.any(hit)
    hit_idx = jnp.argmax(hit)
    free = ~valid
    any_free = jnp.any(free)
    free_idx = jnp.argmax(free)
    oldest_idx = jnp.argmin(jnp.where(valid, t.ages, _SEQ_MAX))
    slot = jnp.where(present, hit_idx, jnp.where(any_free, free_idx, oldest_idx))
    evict = (~present) & (~any_free)
    evicted_addr = jnp.where(evict, t.addrs[slot], INVALID)
    evicted_ptr = jnp.where(evict, t.ptrs[slot], INVALID)
    return (
        LRTbl(
            addrs=t.addrs.at[slot].set(addr),
            ptrs=t.ptrs.at[slot].set(jnp.asarray(ptr, jnp.int32)),
            ages=t.ages.at[slot].set(t.next_age),
            next_age=t.next_age + 1,
        ),
        evicted_addr,
        evicted_ptr,
    )


def lr_lookup(t: LRTbl, addr: jnp.ndarray) -> jnp.ndarray:
    """Return recorded sFIFO position for addr, or -1."""
    hit = (t.addrs == addr) & (t.addrs >= 0)
    return jnp.where(jnp.any(hit), t.ptrs[jnp.argmax(hit)], INVALID)


def lr_remove(t: LRTbl, addr: jnp.ndarray) -> LRTbl:
    hit = (t.addrs == addr) & (t.addrs >= 0)
    return t._replace(addrs=jnp.where(hit, INVALID, t.addrs))


def lr_clear(t: LRTbl) -> LRTbl:
    return t._replace(addrs=jnp.full_like(t.addrs, INVALID))


class PATbl(NamedTuple):
    addrs: jnp.ndarray        # [cap] int32, -1 free
    promote_all: jnp.ndarray  # [] bool — sticky overflow bit


def pa_make(capacity: int) -> PATbl:
    return PATbl(
        addrs=jnp.full((capacity,), INVALID, jnp.int32),
        promote_all=jnp.asarray(False),
    )


def pa_insert(t: PATbl, addr: jnp.ndarray) -> PATbl:
    addr = jnp.asarray(addr, jnp.int32)
    valid = t.addrs >= 0
    present = jnp.any((t.addrs == addr) & valid)
    free = ~valid
    any_free = jnp.any(free)
    free_idx = jnp.argmax(free)
    do_insert = (~present) & any_free
    overflow = (~present) & (~any_free)
    addrs = jnp.where(do_insert, t.addrs.at[free_idx].set(addr), t.addrs)
    return PATbl(addrs=addrs, promote_all=t.promote_all | overflow)


def pa_contains(t: PATbl, addr: jnp.ndarray) -> jnp.ndarray:
    """True if the next local acquire of addr must be promoted."""
    hit = jnp.any((t.addrs == addr) & (t.addrs >= 0))
    return hit | t.promote_all


def pa_clear(t: PATbl) -> PATbl:
    return PATbl(addrs=jnp.full_like(t.addrs, INVALID),
                 promote_all=jnp.asarray(False))
