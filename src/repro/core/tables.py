"""LR-TBL and PA-TBL — the two new hardware structures sRSP adds (paper §4).

LR-TBL (Local-Release Table): small CAM mapping
    sync-variable address -> sFIFO position of the last local release.
A selective-flush probe consults it; only the cache holding an entry for the
probed address drains its sFIFO up to the recorded position.

PA-TBL (Promoted-Acquire Table): set of addresses whose *next* local-scope
acquire must be promoted to global scope (paper §4.3/4.4).

Both tables are **set-associative with per-address LRU aging** behind a
`TableGeometry` (sets × ways) config — DESIGN.md §8.  An address maps to
set `(addr >> 4) % sets` (sync variables are block-spaced, so the block
index spreads); within a set, every insert/update refreshes the entry's
age (`pa_probe` additionally refreshes on a read hit) and a full set
evicts its least-recently-used way.

Overflow policies (the paper sizes the tables small and does not specify
overflow; DESIGN.md §8):
  * LR-TBL eviction returns the evicted (addr, ptr) so the protocol can
    conservatively drain up to that position — no release record is ever
    silently dropped (memory-model preserving, as before).
  * PA-TBL overflow evicts the set's coldest address *silently* instead of
    the pre-geometry sticky global `promote_all` bit: promotion stays
    selective under directory-shaped pressure (many one-shot remote locks),
    at the cost of a bounded, aging-protected staleness window documented
    in DESIGN.md §8 — hot entries are refreshed on every re-insert and
    probe hit, so only addresses that are remotely released and then not
    touched for `ways` same-set insertions can lose their promotion record.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

INVALID = jnp.int32(-1)
_SEQ_MAX = jnp.int32(2**30)


@dataclasses.dataclass(frozen=True)
class TableGeometry:
    """sets × ways layout of a CAM table.  `sets=1` is fully associative;
    `ways=1` is direct-mapped.  Hashable so it can ride in the frozen
    configs that key jit caches."""
    sets: int = 1
    ways: int = 8

    @property
    def capacity(self) -> int:
        return self.sets * self.ways

    def __str__(self) -> str:
        return f"{self.sets}x{self.ways}"


# defaults: LR keeps the historical capacity 8; PA grows to 32 entries so
# directory-shaped broadcast storms evict cold entries instead of hot ones
# (DESIGN.md §8 — a 32-entry CAM is still small hardware)
LR_GEOMETRY = TableGeometry(sets=2, ways=4)
PA_GEOMETRY = TableGeometry(sets=8, ways=4)


def _as_geometry(g: Union[TableGeometry, int]) -> TableGeometry:
    """Accept a bare capacity (legacy callers/tests) as fully associative."""
    if isinstance(g, TableGeometry):
        return g
    return TableGeometry(sets=1, ways=int(g))


def set_index(n_sets: int, addr) -> jnp.ndarray:
    """Home set of `addr`: block index mod sets, at the paper's fixed
    64B/16-word block granule (Table 1 — the same constant the workloads
    bake into their strides/QMETA).  Sync variables are block-spaced in
    every workload, so this spreads them; a ProtoConfig with a smaller
    `block_words` would coarsen the distribution (adjacent sync blocks
    sharing a set), not break correctness.  jnp.mod keeps negative
    (INVALID) probes in range."""
    return jnp.mod(jnp.asarray(addr, jnp.int32) >> 4, jnp.int32(n_sets))


class LRTbl(NamedTuple):
    addrs: jnp.ndarray  # [sets, ways] int32, -1 free
    ptrs: jnp.ndarray   # [sets, ways] int32 sFIFO seq positions
    ages: jnp.ndarray   # [sets, ways] int32 last-touch order (LRU aging)
    next_age: jnp.ndarray  # [] int32


def lr_make(geom: Union[TableGeometry, int] = LR_GEOMETRY) -> LRTbl:
    g = _as_geometry(geom)
    return LRTbl(
        addrs=jnp.full((g.sets, g.ways), INVALID, jnp.int32),
        ptrs=jnp.zeros((g.sets, g.ways), jnp.int32),
        ages=jnp.zeros((g.sets, g.ways), jnp.int32),
        next_age=jnp.int32(0),
    )


def lr_insert(t: LRTbl, addr: jnp.ndarray, ptr: jnp.ndarray
              ) -> Tuple[LRTbl, jnp.ndarray, jnp.ndarray]:
    """Insert or update addr -> ptr in addr's set; refresh the entry's age.
    Returns (tbl', evicted_addr, evicted_ptr) — the LRU victim's record
    when the set was full (-1, -1 otherwise)."""
    addr = jnp.asarray(addr, jnp.int32)
    s = set_index(t.addrs.shape[0], addr)
    row_a, row_p, row_g = t.addrs[s], t.ptrs[s], t.ages[s]
    valid = row_a >= 0
    hit = (row_a == addr) & valid
    present = jnp.any(hit)
    free = ~valid
    any_free = jnp.any(free)
    way = jnp.where(present, jnp.argmax(hit),
                    jnp.where(any_free, jnp.argmax(free),
                              jnp.argmin(jnp.where(valid, row_g, _SEQ_MAX))))
    evict = (~present) & (~any_free)
    evicted_addr = jnp.where(evict, row_a[way], INVALID)
    evicted_ptr = jnp.where(evict, row_p[way], INVALID)
    return (
        LRTbl(
            addrs=t.addrs.at[s, way].set(addr),
            ptrs=t.ptrs.at[s, way].set(jnp.asarray(ptr, jnp.int32)),
            ages=t.ages.at[s, way].set(t.next_age),
            next_age=t.next_age + 1,
        ),
        evicted_addr,
        evicted_ptr,
    )


def lr_lookup(t: LRTbl, addr: jnp.ndarray) -> jnp.ndarray:
    """Return recorded sFIFO position for addr, or -1 (read-only probe;
    a protocol probe hit is always followed by lr_remove, so there is no
    age to refresh)."""
    addr = jnp.asarray(addr, jnp.int32)
    s = set_index(t.addrs.shape[0], addr)
    row = t.addrs[s]
    hit = (row == addr) & (row >= 0)
    return jnp.where(jnp.any(hit), t.ptrs[s][jnp.argmax(hit)], INVALID)


def lr_remove(t: LRTbl, addr: jnp.ndarray) -> LRTbl:
    addr = jnp.asarray(addr, jnp.int32)
    s = set_index(t.addrs.shape[0], addr)
    row = t.addrs[s]
    hit = (row == addr) & (row >= 0)
    return t._replace(addrs=t.addrs.at[s].set(jnp.where(hit, INVALID, row)))


def lr_reset(t: LRTbl) -> LRTbl:
    """Full clear, geometry derived from the *live* table (never from
    config literals — a custom TableGeometry must survive resets)."""
    return t._replace(addrs=jnp.full_like(t.addrs, INVALID))


lr_clear = lr_reset  # historical name


class PATbl(NamedTuple):
    addrs: jnp.ndarray     # [sets, ways] int32, -1 free
    ages: jnp.ndarray      # [sets, ways] int32 last-touch order (LRU aging)
    next_age: jnp.ndarray  # [] int32


def pa_make(geom: Union[TableGeometry, int] = PA_GEOMETRY) -> PATbl:
    g = _as_geometry(geom)
    return PATbl(
        addrs=jnp.full((g.sets, g.ways), INVALID, jnp.int32),
        ages=jnp.zeros((g.sets, g.ways), jnp.int32),
        next_age=jnp.int32(0),
    )


def pa_insert(t: PATbl, addr: jnp.ndarray) -> PATbl:
    """Record addr in its set; re-insert refreshes the age (hot entries —
    locks that keep getting remotely released — stay resident).  A full
    set evicts its LRU way silently (DESIGN.md §8)."""
    addr = jnp.asarray(addr, jnp.int32)
    s = set_index(t.addrs.shape[0], addr)
    row_a, row_g = t.addrs[s], t.ages[s]
    valid = row_a >= 0
    hit = (row_a == addr) & valid
    present = jnp.any(hit)
    free = ~valid
    any_free = jnp.any(free)
    way = jnp.where(present, jnp.argmax(hit),
                    jnp.where(any_free, jnp.argmax(free),
                              jnp.argmin(jnp.where(valid, row_g, _SEQ_MAX))))
    return PATbl(
        addrs=t.addrs.at[s, way].set(addr),
        ages=t.ages.at[s, way].set(t.next_age),
        next_age=t.next_age + 1,
    )


def pa_contains(t: PATbl, addr: jnp.ndarray) -> jnp.ndarray:
    """True if the next local acquire of addr must be promoted (pure hit
    check — no global promote_all fallback anymore)."""
    addr = jnp.asarray(addr, jnp.int32)
    row = t.addrs[set_index(t.addrs.shape[0], addr)]
    return jnp.any((row == addr) & (row >= 0))


def pa_probe(t: PATbl, addr: jnp.ndarray) -> Tuple[PATbl, jnp.ndarray]:
    """`pa_contains` that also refreshes the hit entry's age (LRU aging on
    probe) — for acquire paths that would NOT consume the entry.  The
    current engine always consumes a hit (promotion invalidates, which
    resets the table), so `local_acquire_b` uses the pure `pa_contains`;
    this is the aging API a non-consuming consumer would bind instead."""
    addr = jnp.asarray(addr, jnp.int32)
    s = set_index(t.addrs.shape[0], addr)
    row = t.addrs[s]
    hit = (row == addr) & (row >= 0)
    present = jnp.any(hit)
    ages = t.ages.at[s, jnp.argmax(hit)].set(
        jnp.where(present, t.next_age, t.ages[s, jnp.argmax(hit)]))
    return t._replace(ages=ages,
                      next_age=t.next_age + present.astype(jnp.int32)), present


def pa_reset(t: PATbl) -> PATbl:
    """Full clear, geometry derived from the *live* table — never rebuilt
    from default literals, so configured sets/ways survive every reset
    (the invalidation path calls this on each full invalidate)."""
    return t._replace(addrs=jnp.full_like(t.addrs, INVALID))


pa_clear = pa_reset  # historical name
