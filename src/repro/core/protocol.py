"""Functional model of the sRSP / RSP scoped-synchronization protocols (paper §2–4).

The memory system is modeled at *block granularity* over a shared L2 (the
global synchronization point) and N private L1 caches, the write-combining,
no-allocate hierarchy of the paper's Table 1.  The layout is block-major
(DESIGN.md §1): every array is shaped so that one cache block is one
contiguous row, which turns the flush machinery into single gather/scatter
ops instead of per-word dynamic slices:

    Store.l2      [n_blocks, block_words]            word values at L2
    Store.l1      [n_caches, n_blocks, block_words]  per-cache cached values
    Store.wvalid  [n_caches, n_blocks, ceil(W/32)]   local copy is readable
    Store.wdirty  [n_caches, n_blocks, ceil(W/32)]   local copy not written back
    Store.fifo    batched SFifo        dirty-block FIFO  (QuickRelease)
    Store.lr      batched LRTbl        sRSP local-release table (set-assoc)
    Store.pa      batched PATbl        sRSP promoted-acquire table (set-assoc)

A flat word address `addr` maps to (addr // block_words, addr % block_words).

The per-word metadata planes `wvalid`/`wdirty` are **packed uint32
word-bitmasks** (`core/bitmask.py`, DESIGN.md §8): bit `o % 32` of lane
`o // 32` tracks block offset `o`, so the planes carry 1 bit per word
instead of the boolean layout's byte — the in-loop scatters that bound the
batched engine at n_wgs=256 shrink with them.  `REPRO_NO_PACK=1` (read
once at import, mirroring REPRO_NO_DONATE) falls back to the boolean
`[n_caches, n_blocks, W]` layout; the sweep A/B-tests the two in
subprocesses.  All plane access goes through the `_pl_*`/`_rows_*`
helpers below, which are the only layout-aware code.

All operations are pure `(store, ...) -> (store', ...)` functions and fully
jittable; the cost model charges cycles/L2-transactions as a side channel in
`store.counters`.  Stale data is *really modeled*: an L1 may hold an old
copy of a word while L2 has moved on — a protocol bug shows up as a wrong
value read by a work-stealer, which the integration tests catch end-to-end.

Two API layers (DESIGN.md §3):

  * the classic single-cache ops (`load`, `store_word`, `local_acquire`, …)
    take a scalar `cid` and are what the protocol tests and the serial
    work-steal engine use;
  * the batched multi-cache ops (`b_load`, `b_store_word`,
    `local_acquire_b`, …) take an `active [n_caches]` mask plus per-cache
    operand vectors and execute one op *per cache* in a single set of array
    ops.  They are only semantics-preserving when the active caches touch
    pairwise-disjoint L2 words (the batched scheduler in worksteal.py
    guarantees this); cross-cache writeback merges resolve block-level
    false sharing deterministically (highest cache id wins per word, which
    matches the serial engine's ascending-j drain order).

Workload code should not bind these functions directly: the
scope-parametric instruction layer `repro.core.ops`
(`acquire/release/load/store(..., scope=LOCAL|REMOTE|GLOBAL)`,
DESIGN.md §9) dispatches into a registered `Protocol`'s per-scope op
table, including the batched address-disjoint remote twins
(`srsp_remote_acquire_b`/`srsp_remote_release_b`) that let the harness
co-schedule non-conflicting remote turns.

Invariant maintained (checked by property tests): every dirty word's block
is present in that cache's sFIFO, so a FIFO drain is a complete flush.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bitmask, sfifo, tables
from repro.core.costmodel import CostParams, Counters, make_counters
from repro.kernels.fused_turn import plane_commit
from repro.kernels.selective_flush.ops import drain_writeback
from repro.obs import trace as obs

INVALID = jnp.int32(-1)
# Public drain-everything sentinel for the `pos` argument of the drain ops
# (any seq is <= it, so the whole sFIFO drains).  `_DRAIN_ALL` is the
# historical private alias.
DRAIN_ALL = jnp.int32(2**30)
_DRAIN_ALL = DRAIN_ALL

# Metadata layout toggle, read once at import (the jitted schedulers are
# module-level, so the flag must be process-wide; the sweep A/Bs it in
# subprocesses).  Default: packed uint32 word-bitmasks (DESIGN.md §8).
PACKED = os.environ.get("REPRO_NO_PACK", "0") != "1"


@dataclasses.dataclass(frozen=True)
class ProtoConfig:
    n_caches: int
    n_words: int
    block_words: int = 16      # 64B block / 4B word (Table 1)
    fifo_cap: int = 16         # L1 sFIFO entries (Table 1)
    lr_tbl: tables.TableGeometry = tables.LR_GEOMETRY   # sets × ways
    pa_tbl: tables.TableGeometry = tables.PA_GEOMETRY   # sets × ways
    params: CostParams = dataclasses.field(default_factory=CostParams)

    @property
    def n_blocks(self) -> int:
        return (self.n_words + self.block_words - 1) // self.block_words

    @property
    def meta_lanes(self) -> int:
        """Last-axis extent of the wvalid/wdirty planes in this layout."""
        return bitmask.n_lanes(self.block_words) if PACKED \
            else self.block_words


class Lease(NamedTuple):
    """Clock-stamped sync-word lease, one per cache (elastic alive-set PR).

    `addr[i]` is the L2 sync word cache i's last acquire targeted (INVALID
    once released) and `stamp[i]` the per-cache cycle clock at that
    acquire.  The scoped ISA (`repro.core.ops`) stamps these on every
    acquire/release as pure bookkeeping — no cycles, no counters — so the
    zero-churn schedule stays bitwise identical.  `b_recover` reads the
    lease to release a dead holder's sync word after its lease expires."""
    addr: jnp.ndarray      # [n_caches] i32 held sync word, INVALID if none
    stamp: jnp.ndarray     # [n_caches] f32 cycle clock at acquire


def lease_make(n_caches: int) -> Lease:
    return Lease(addr=jnp.full((n_caches,), INVALID),
                 stamp=jnp.zeros((n_caches,), jnp.float32))


def lease_stamp(st: "Store", active, addrs) -> "Store":
    """Record an acquire: active lanes now hold `addrs` as of their clock."""
    active = jnp.asarray(active, bool)
    return st._replace(lease=Lease(
        addr=jnp.where(active, jnp.asarray(addrs, jnp.int32), st.lease.addr),
        stamp=jnp.where(active, st.counters.cycles, st.lease.stamp)))


def lease_clear(st: "Store", active) -> "Store":
    """Record a release: active lanes hold nothing."""
    active = jnp.asarray(active, bool)
    return st._replace(lease=Lease(
        addr=jnp.where(active, INVALID, st.lease.addr),
        stamp=jnp.where(active, 0.0, st.lease.stamp)))


class Store(NamedTuple):
    l2: jnp.ndarray        # [n_blocks, W]
    l1: jnp.ndarray        # [n_caches, n_blocks, W]
    wvalid: jnp.ndarray    # [n_caches, n_blocks, meta_lanes] (see PACKED)
    wdirty: jnp.ndarray    # [n_caches, n_blocks, meta_lanes]
    fifo: sfifo.SFifo      # leaves have leading [n_caches]
    lr: tables.LRTbl
    pa: tables.PATbl
    lease: Lease           # clock-stamped sync-word leases (crash recovery)
    counters: Counters
    trace: obs.TraceLog    # event ring + latency hists; empty unless traced


def make_store(cfg: ProtoConfig) -> Store:
    n, nb, w = cfg.n_caches, cfg.n_blocks, cfg.block_words
    plane = jnp.zeros((n, nb, cfg.meta_lanes),
                      jnp.uint32 if PACKED else jnp.bool_)
    stack = lambda t: jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), t)
    return Store(
        l2=jnp.zeros((nb, w), jnp.int32),
        l1=jnp.zeros((n, nb, w), jnp.int32),
        wvalid=plane,
        wdirty=plane.copy(),
        fifo=stack(sfifo.make(cfg.fifo_cap)),
        lr=stack(tables.lr_make(cfg.lr_tbl)),
        pa=stack(tables.pa_make(cfg.pa_tbl)),
        lease=lease_make(n),
        counters=make_counters(n),
        trace=obs.make(obs.default_cap(), n),
    )


# --------------------------------------------------------------------------
# batched sub-structure helpers
# --------------------------------------------------------------------------

def _get(tree, cid):
    return jax.tree.map(lambda x: x[cid], tree)


def _set(tree, cid, sub):
    return jax.tree.map(lambda b, s: b.at[cid].set(s), tree, sub)


def _mask_tree(pred, new, old):
    """Select `new` where pred else `old` (same structure)."""
    return jax.tree.map(lambda n, o: jnp.where(pred, n, o), new, old)


def _mask_tree_rows(pred, new, old):
    """Per-cache select: pred [n_caches], leaves have leading [n_caches]."""
    def sel(n, o):
        p = pred.reshape(pred.shape + (1,) * (n.ndim - 1))
        return jnp.where(p, n, o)
    return jax.tree.map(sel, new, old)


def _blk(cfg: ProtoConfig, addr):
    return addr // cfg.block_words


def _split(cfg: ProtoConfig, addr):
    addr = jnp.asarray(addr, jnp.int32)
    return addr // cfg.block_words, addr % cfg.block_words


def _one_hot(cfg: ProtoConfig, cid):
    return jnp.arange(cfg.n_caches, dtype=jnp.int32) == jnp.asarray(cid, jnp.int32)


def _fill(cfg: ProtoConfig, val):
    return jnp.full((cfg.n_caches,), val, jnp.int32)


# --------------------------------------------------------------------------
# metadata-plane access — the ONLY layout-aware code (packed vs boolean)
# --------------------------------------------------------------------------

def _pl_get(plane, lane, b, o):
    """Per-lane flag read: flags[lane, b, o] -> bool [n]."""
    if PACKED:
        return bitmask.test_word(plane[lane, b, bitmask.word_index(o)], o)
    return plane[lane, b, o]


def _pl_set(plane, lane, b, o, on):
    """Per-lane flag OR: flags[lane, b, o] |= on (lanes with on=False keep
    their value; (lane, b) pairs are distinct, so the scatter is safe)."""
    if PACKED:
        w = bitmask.word_index(o)
        mask = jnp.where(jnp.asarray(on, bool), bitmask.word_bit(o),
                         jnp.uint32(0))
        return plane.at[lane, b, w].set(plane[lane, b, w] | mask)
    return plane.at[lane, b, o].set(plane[lane, b, o] | on)


def _pl_clear(plane, lane, b, o, off):
    """Per-lane flag clear: flags[lane, b, o] &= ~off."""
    if PACKED:
        w = bitmask.word_index(o)
        mask = jnp.where(jnp.asarray(off, bool), bitmask.word_bit(o),
                         jnp.uint32(0))
        return plane.at[lane, b, w].set(plane[lane, b, w] & ~mask)
    return plane.at[lane, b, o].set(plane[lane, b, o] & ~off)


def _rows_where(g, rows):
    """Row select under a guard: rows where g[...] else all-clear.  Works
    on boolean [..., W] and packed [..., L] rows alike."""
    return jnp.where(g[..., None], rows, jnp.zeros((), rows.dtype))


def _rows_any(rows):
    """Per-row any-flag-set; layout-independent (bool != 0 is identity)."""
    return jnp.any(rows != 0, axis=-1)


def plane_scatter_set(plane, lane, b, o):
    """Bulk flag OR over index triples (the write-combining bulk-store
    path, e.g. worksteal's enqueue scatter).  Triples must be distinct;
    out-of-range b drops.  Packed lanes accumulate by add, which equals OR
    exactly because each (lane, b, o) bit appears at most once."""
    if PACKED:
        pattern = jnp.zeros_like(plane).at[
            lane, b, bitmask.word_index(o)].add(bitmask.word_bit(o),
                                                mode="drop")
        return plane | pattern
    return plane.at[lane, b, o].set(True, mode="drop")


def wvalid_bool(st: Store) -> jnp.ndarray:
    """Boolean [n_caches, n_blocks, W] view of wvalid (tests/debug)."""
    return bitmask.unpack(st.wvalid, st.l1.shape[-1]) if PACKED else st.wvalid


def wdirty_bool(st: Store) -> jnp.ndarray:
    """Boolean [n_caches, n_blocks, W] view of wdirty (tests/debug)."""
    return bitmask.unpack(st.wdirty, st.l1.shape[-1]) if PACKED else st.wdirty


# --------------------------------------------------------------------------
# batched block writeback / drain core  (önbellek-temizleme machinery, §2.2)
# --------------------------------------------------------------------------

def b_writeback(cfg: ProtoConfig, st: Store, blks, guard) -> Tuple[Store, jnp.ndarray]:
    """Write back one block per cache: cache i flushes the dirty words of
    block `blks[i]` (skip where guard[i] is False or blks[i] < 0).

    Cross-cache collisions on the same block merge per word, highest cache
    id winning (matches the serial ascending-j order; see module docstring).
    Returns (store', did [n_caches] f32 — 1.0 where any word moved)."""
    n, nb, W = cfg.n_caches, cfg.n_blocks, cfg.block_words
    blks = jnp.asarray(blks, jnp.int32)
    g = jnp.asarray(guard, bool) & (blks >= 0)
    safe = jnp.clip(blks, 0)
    rows = st.l1[jnp.arange(n), safe]                       # [n, W]
    dirty_rows = st.wdirty[jnp.arange(n), safe]             # [n, L]
    sel = _rows_where(g, dirty_rows)
    idx = jnp.where(g, safe, nb)
    l2 = drain_writeback(st.l2, rows, sel, idx)
    wdirty = st.wdirty.at[jnp.arange(n), idx].set(
        dirty_rows & ~sel, mode="drop")
    did = _rows_any(sel).astype(jnp.float32)
    tot = jnp.sum(did)
    c = st.counters
    c = c._replace(l2_accesses=c.l2_accesses + tot, wb_blocks=c.wb_blocks + tot)
    return st._replace(l2=l2, wdirty=wdirty, counters=c), did


def b_drain(cfg: ProtoConfig, st: Store, pos, charge) -> Tuple[Store, jnp.ndarray]:
    """Selective flush, all caches at once: cache i drains its sFIFO up to
    seq `pos[i]` (§4.2 step 3; pos<0 drains nothing, big pos drains all) and
    writes every drained block back to L2 in one masked scatter.

    `charge[i]` mirrors the serial engine's per-call accounting: a charged
    cache pays l2_lat + n_wb*wb_per_block even when it drained nothing.
    Returns (store', n_wb [n_caches] f32)."""
    n, nb, W = cfg.n_caches, cfg.n_blocks, cfg.block_words
    pos = jnp.asarray(pos, jnp.int32)
    f2, drained, _ = jax.vmap(sfifo.drain_upto)(st.fifo, pos)   # drained [n, cap]
    st = st._replace(fifo=f2)
    cap = drained.shape[1]
    g = drained >= 0
    safe = jnp.clip(drained, 0)
    crow = jnp.broadcast_to(jnp.arange(n)[:, None], (n, cap))
    rows = st.l1[crow, safe]                                    # [n, cap, W]
    dirty_rows = _rows_where(g, st.wdirty[crow, safe])          # [n, cap, L]
    idx = jnp.where(g, drained, nb)
    # cache-major flatten: later caches override earlier on (racy) collisions
    l2 = drain_writeback(st.l2, rows.reshape(n * cap, W),
                         dirty_rows.reshape(n * cap, dirty_rows.shape[-1]),
                         idx.reshape(n * cap))
    wdirty = st.wdirty.at[crow, idx].set(
        st.wdirty[crow, safe] & ~dirty_rows, mode="drop")
    did = _rows_any(dirty_rows)                                 # [n, cap]
    n_wb = jnp.sum(did, axis=1).astype(jnp.float32)
    tot = jnp.sum(n_wb)
    p = cfg.params
    charge = jnp.asarray(charge, bool)
    cyc = jnp.where(charge, p.l2_lat + n_wb * p.wb_per_block, 0.0)
    c = st.counters
    c = c._replace(cycles=c.cycles + cyc,
                   l2_accesses=c.l2_accesses + tot,
                   wb_blocks=c.wb_blocks + tot)
    return st._replace(l2=l2, wdirty=wdirty, counters=c), n_wb


def b_invalidate(cfg: ProtoConfig, st: Store, mask) -> Store:
    """Whole-cache invalidate of every cache in `mask`: flush dirty first
    (§2.2), flash-invalidate, clear LR-TBL and PA-TBL (§4.4)."""
    mask = jnp.asarray(mask, bool)
    st, _ = b_drain(cfg, st, jnp.where(mask, _DRAIN_ALL, INVALID), mask)
    wvalid = jnp.where(mask[:, None, None],
                       jnp.zeros((), st.wvalid.dtype), st.wvalid)
    # geometry-deriving resets (full_like on the live tables): a custom
    # TableGeometry survives every invalidate
    lr = _mask_tree_rows(mask, jax.vmap(tables.lr_reset)(st.lr), st.lr)
    pa = _mask_tree_rows(mask, jax.vmap(tables.pa_reset)(st.pa), st.pa)
    p = cfg.params
    fmask = mask.astype(jnp.float32)
    c = st.counters
    c = c._replace(cycles=c.cycles + fmask * p.inv_flash,
                   inv_full=c.inv_full + jnp.sum(fmask),
                   inv_per_cache=c.inv_per_cache + fmask)
    return st._replace(wvalid=wvalid, lr=lr, pa=pa, counters=c)


def b_recover(cfg: ProtoConfig, st: Store, mask) -> Store:
    """Crash-recovery drain for every cache in `mask` (dead agents whose
    lease expired — elastic alive-set PR, DESIGN.md §10):

      1. reclaim the dead cache's dirty words: full drain + writeback via
         the existing flush machinery, then flash-invalidate and clear its
         LR/PA entries (`b_invalidate` — a dead agent must never again be
         probed as a sharer or promoted);
      2. force-release its leased sync word at L2 (ST 0) so waiting remote
         acquirers stop CAS-failing against a dead holder;
      3. clear the lease and count one recovery per reclaimed cache.

    With `mask` all-False this is value-preserving except for +0.0 counter
    adds, but the elastic schedulers additionally guard the call under a
    `lax.cond` so zero-churn runs never execute it at all."""
    mask = jnp.asarray(mask, bool)
    clock0 = st.counters.cycles
    la = st.lease.addr
    st = b_invalidate(cfg, st, mask)
    rel = mask & (la >= 0)
    st, _ = b_atomic_l2(cfg, st, rel, jnp.clip(la, 0),
                        _fill(cfg, 0), _fill(cfg, 0), False)
    st = lease_clear(st, mask)
    c = st.counters
    c = c._replace(recoveries=c.recoveries
                   + jnp.sum(mask.astype(jnp.float32)))
    st = st._replace(counters=c)
    # observability: one RECOVER event per reclaimed cache, stamped with
    # the leased address the drain force-released (identity when off)
    return obs.record_event(st, mask, obs.RECOVER, obs.OC_RECOVER,
                            addr=la, clock=clock0,
                            cycles=st.counters.cycles - clock0)


# --------------------------------------------------------------------------
# single-cache wrappers (classic API, used by tests + serial engine)
# --------------------------------------------------------------------------

def writeback_block(cfg: ProtoConfig, st: Store, cid, b, guard=True
                    ) -> Tuple[Store, jnp.ndarray]:
    """Write back the dirty words of block `b` of cache `cid` to L2.

    Returns (store', did_wb) where did_wb is 1.0 if any word moved.
    With guard=False or b<0 this is a no-op (used in padded batches)."""
    hot = _one_hot(cfg, cid)
    blks = jnp.where(hot, jnp.asarray(b, jnp.int32), INVALID)
    st, did = b_writeback(cfg, st, blks, hot & jnp.asarray(guard, bool))
    return st, jnp.sum(did)


def drain_fifo(cfg: ProtoConfig, st: Store, cid, pos) -> Tuple[Store, jnp.ndarray]:
    """Selective flush: drain cache `cid`'s sFIFO up to seq `pos` (§4.2 step
    3), writing each drained block back to L2.  pos<0 drains nothing;
    pos=+inf (use drain_fifo_all) drains everything.

    Returns (store', n_blocks_written)."""
    hot = _one_hot(cfg, cid)
    st, n_wb = b_drain(cfg, st, jnp.where(hot, jnp.asarray(pos, jnp.int32),
                                          INVALID), hot)
    return st, jnp.sum(n_wb)


def drain_fifo_all(cfg: ProtoConfig, st: Store, cid) -> Tuple[Store, jnp.ndarray]:
    return drain_fifo(cfg, st, cid, _DRAIN_ALL)


def invalidate_cache(cfg: ProtoConfig, st: Store, cid) -> Store:
    return b_invalidate(cfg, st, _one_hot(cfg, cid))


# --------------------------------------------------------------------------
# plain loads / stores through the cache — batched core + scalar wrappers
# --------------------------------------------------------------------------

def b_load(cfg: ProtoConfig, st: Store, active, addrs
           ) -> Tuple[Store, jnp.ndarray]:
    """Ordinary read, one per active cache.  L1 hit or fill-from-L2
    (read-allocate).  addrs [n_caches] must be valid even for inactive
    lanes (they are read but not written)."""
    n = cfg.n_caches
    active = jnp.asarray(active, bool)
    b, o = _split(cfg, addrs)
    lane = jnp.arange(n)
    # fused metadata front-end (kernels/fused_turn, DESIGN.md §12): the
    # pre-op valid bit (the L1 hit — also ops.load's OC_HIT/OC_MISS
    # classification) and the plane OR come from one plane_commit pass
    wvalid, _, hit, _ = plane_commit(st.wvalid, st.wdirty, b, o,
                                     active, None)
    val = jnp.where(hit, st.l1[lane, b, o], st.l2[b, o])
    l1 = st.l1.at[lane, b, o].set(jnp.where(active, val, st.l1[lane, b, o]))
    p = cfg.params
    miss = active & ~hit
    c = st.counters
    c = c._replace(
        cycles=c.cycles + jnp.where(
            active, jnp.where(hit, p.l1_lat, p.l1_lat + p.l2_lat), 0.0),
        l1_hits=c.l1_hits + jnp.sum((active & hit).astype(jnp.float32)),
        l1_misses=c.l1_misses + jnp.sum(miss.astype(jnp.float32)),
        l2_accesses=c.l2_accesses + jnp.sum(miss.astype(jnp.float32)),
    )
    return st._replace(l1=l1, wvalid=wvalid, counters=c), val


def b_store_word(cfg: ProtoConfig, st: Store, active, addrs, vals,
                 force_tail=False) -> Tuple[Store, jnp.ndarray]:
    """Ordinary write (write-combining, no-allocate), one per active cache:
    update local copy, mark dirty, record the block in the sFIFO.  Capacity
    eviction writes the oldest block back (§2.2).
    Returns (store', fifo_pos_of_block [n_caches])."""
    n = cfg.n_caches
    active = jnp.asarray(active, bool)
    b, o = _split(cfg, addrs)
    lane = jnp.arange(n)
    l1 = st.l1.at[lane, b, o].set(
        jnp.where(active, jnp.asarray(vals, jnp.int32), st.l1[lane, b, o]))
    # both plane scatters fused into one plane_commit pass (the packed
    # Pallas kernel on TPU; the was_dirty pre-state it also returns is
    # ops.store's write-combining classification bit)
    wvalid, wdirty, _, _ = plane_commit(st.wvalid, st.wdirty, b, o,
                                        active, active)
    st = st._replace(l1=l1, wvalid=wvalid, wdirty=wdirty)

    ft = jnp.broadcast_to(jnp.asarray(force_tail, bool), (n,))
    f2, evicted, pos = jax.vmap(sfifo.push)(st.fifo, b, ft)
    fifo = _mask_tree_rows(active, f2, st.fifo)
    evicted = jnp.where(active, evicted, INVALID)
    st = st._replace(fifo=fifo)
    st, n_evwb = b_writeback(cfg, st, evicted, evicted >= 0)
    p = cfg.params
    c = st.counters
    c = c._replace(cycles=c.cycles + jnp.where(
        active, p.l1_lat + n_evwb * p.wb_per_block, 0.0))
    return st._replace(counters=c), pos


def load(cfg: ProtoConfig, st: Store, cid, addr) -> Tuple[Store, jnp.ndarray]:
    """Ordinary read.  L1 hit or fill-from-L2 (read-allocate)."""
    st, vals = b_load(cfg, st, _one_hot(cfg, cid), _fill(cfg, addr))
    return st, vals[cid]


def store_word(cfg: ProtoConfig, st: Store, cid, addr, val, *, force_tail=False,
               guard=True) -> Tuple[Store, jnp.ndarray]:
    """Ordinary write through cache `cid`.  Returns (store', fifo_pos)."""
    hot = _one_hot(cfg, cid) & jnp.asarray(guard, bool)
    st, pos = b_store_word(cfg, st, hot, _fill(cfg, addr),
                           jnp.broadcast_to(jnp.asarray(val, jnp.int32),
                                            (cfg.n_caches,)),
                           force_tail)
    return st, pos[cid]


# --------------------------------------------------------------------------
# atomics
# --------------------------------------------------------------------------

def b_atomic_l1(cfg, st: Store, active, addrs, expect, new, is_cas
                ) -> Tuple[Store, jnp.ndarray]:
    """Atomic executed at the L1 (local scope), one per active cache.
    Returns (store', old_values [n_caches])."""
    st, cur = b_load(cfg, st, active, addrs)
    success = jnp.where(is_cas, cur == expect, True)
    st, _ = b_store_word(cfg, st, jnp.asarray(active, bool) & success, addrs,
                         jnp.where(success, new, cur))
    return st, cur


def b_atomic_l2(cfg, st: Store, active, addrs, expect, new, is_cas
                ) -> Tuple[Store, jnp.ndarray]:
    """Atomic executed at the L2 (global sync point), one per active cache.
    Active lanes must target pairwise-distinct words.  Returns (store', old)."""
    n, nb = cfg.n_caches, cfg.n_blocks
    active = jnp.asarray(active, bool)
    b, o = _split(cfg, addrs)
    lane = jnp.arange(n)
    cur = st.l2[b, o]
    success = jnp.where(is_cas, cur == expect, True)
    write = active & success
    l2 = st.l2.at[jnp.where(write, b, nb), o].set(
        jnp.where(success, jnp.asarray(new, jnp.int32), cur), mode="drop")
    # local copy of this word is no longer authoritative
    wvalid = _pl_clear(st.wvalid, lane, b, o, active)
    wdirty = _pl_clear(st.wdirty, lane, b, o, active)
    p = cfg.params
    fact = active.astype(jnp.float32)
    c = st.counters
    c = c._replace(cycles=c.cycles + fact * p.l2_lat,
                   l2_accesses=c.l2_accesses + jnp.sum(fact))
    return st._replace(l2=l2, wvalid=wvalid, wdirty=wdirty, counters=c), cur


def _atomic_l1(cfg, st: Store, cid, addr, expect, new, is_cas
               ) -> Tuple[Store, jnp.ndarray]:
    st, old = b_atomic_l1(cfg, st, _one_hot(cfg, cid), _fill(cfg, addr),
                          expect, new, is_cas)
    return st, old[cid]


def _atomic_l2(cfg, st: Store, cid, addr, expect, new, is_cas
               ) -> Tuple[Store, jnp.ndarray]:
    st, old = b_atomic_l2(cfg, st, _one_hot(cfg, cid), _fill(cfg, addr),
                          expect, new, is_cas)
    return st, old[cid]


# --------------------------------------------------------------------------
# scoped synchronization — local (work-group) scope, §4.1 / §4.4
# --------------------------------------------------------------------------

def local_release_b(cfg: ProtoConfig, st: Store, active, addrs, vals) -> Store:
    """atomic_ST_rel_wg for every active cache: push the sync block to the
    sFIFO tail, record (addr -> pos) in the LR-TBL, atomic executes in L1."""
    active = jnp.asarray(active, bool)
    st, pos = b_store_word(cfg, st, active, addrs, vals, force_tail=True)
    addrs32 = jnp.asarray(addrs, jnp.int32)
    lr2, ev_addr, ev_ptr = jax.vmap(tables.lr_insert)(st.lr, addrs32, pos)
    st = st._replace(lr=_mask_tree_rows(active, lr2, st.lr))
    # conservative overflow policy: an evicted LR record forces a drain up to
    # its recorded position so no release is silently lost (DESIGN.md §2)
    ev = jnp.where(active & (ev_addr >= 0), ev_ptr, INVALID)
    st, _ = b_drain(cfg, st, ev, active)
    p = cfg.params
    fact = active.astype(jnp.float32)
    c = st.counters
    c = c._replace(cycles=c.cycles + fact * p.tbl_lat,
                   local_syncs=c.local_syncs + jnp.sum(fact))
    return st._replace(counters=c)


def local_acquire_b(cfg: ProtoConfig, st: Store, active, addrs, expect, new
                    ) -> Tuple[Store, jnp.ndarray]:
    """atomic_CAS_acq_wg for every active cache (§4.4).  Lanes whose PA-TBL
    holds the address are promoted: full invalidate + CAS at L2.  Others do
    a cheap L1 CAS.  Both paths execute masked (no lane-level cond)."""
    active = jnp.asarray(active, bool)
    addrs32 = jnp.asarray(addrs, jnp.int32)
    promote = jax.vmap(tables.pa_contains)(st.pa, addrs32) & active
    st = b_invalidate(cfg, st, promote)
    st, old_l2 = b_atomic_l2(cfg, st, promote, addrs, expect, new, True)
    st, old_l1 = b_atomic_l1(cfg, st, active & ~promote, addrs, expect, new,
                             True)
    old = jnp.where(promote, old_l2, old_l1)
    p = cfg.params
    fact = active.astype(jnp.float32)
    c = st.counters
    c = c._replace(cycles=c.cycles + fact * p.tbl_lat,
                   local_syncs=c.local_syncs + jnp.sum(fact),
                   promotions=c.promotions
                   + jnp.sum(promote.astype(jnp.float32)))
    return st._replace(counters=c), old


def local_release(cfg: ProtoConfig, st: Store, cid, addr, val) -> Store:
    return local_release_b(cfg, st, _one_hot(cfg, cid), _fill(cfg, addr),
                           jnp.broadcast_to(jnp.asarray(val, jnp.int32),
                                            (cfg.n_caches,)))


def local_acquire(cfg: ProtoConfig, st: Store, cid, addr, expect, new
                  ) -> Tuple[Store, jnp.ndarray]:
    st, old = local_acquire_b(cfg, st, _one_hot(cfg, cid), _fill(cfg, addr),
                              expect, new)
    return st, old[cid]


# --------------------------------------------------------------------------
# global (device/cmp) scope — the heavyweight ops used by Baseline/Steal-only
# --------------------------------------------------------------------------

def global_release_b(cfg: ProtoConfig, st: Store, active, addrs, vals) -> Store:
    active = jnp.asarray(active, bool)
    st, _ = b_drain(cfg, st, jnp.where(active, _DRAIN_ALL, INVALID), active)
    st, _ = b_atomic_l2(cfg, st, active, addrs, 0, vals, False)
    c = st.counters
    return st._replace(counters=c._replace(
        global_syncs=c.global_syncs + jnp.sum(active.astype(jnp.float32))))


def global_acquire_b(cfg: ProtoConfig, st: Store, active, addrs, expect, new
                     ) -> Tuple[Store, jnp.ndarray]:
    active = jnp.asarray(active, bool)
    st = b_invalidate(cfg, st, active)
    st, old = b_atomic_l2(cfg, st, active, addrs, expect, new, True)
    c = st.counters
    return st._replace(counters=c._replace(
        global_syncs=c.global_syncs
        + jnp.sum(active.astype(jnp.float32)))), old


def global_release(cfg: ProtoConfig, st: Store, cid, addr, val) -> Store:
    return global_release_b(cfg, st, _one_hot(cfg, cid), _fill(cfg, addr),
                            jnp.broadcast_to(jnp.asarray(val, jnp.int32),
                                             (cfg.n_caches,)))


def global_acquire(cfg: ProtoConfig, st: Store, cid, addr, expect, new
                   ) -> Tuple[Store, jnp.ndarray]:
    st, old = global_acquire_b(cfg, st, _one_hot(cfg, cid), _fill(cfg, addr),
                               expect, new)
    return st, old[cid]


# --------------------------------------------------------------------------
# remote scope promotion — sRSP (§4.2, §4.3) and original RSP (§3) variants
# --------------------------------------------------------------------------

def _probe_and_selective_flush(cfg: ProtoConfig, st: Store, cid, addr) -> Store:
    """Broadcast a selective-flush(addr) probe via L2 to every L1 (§4.2 step
    2).  Only caches with an LR-TBL entry for addr drain — up to the
    recorded position — then move addr into their PA-TBL.  Everyone else
    NACKs.  One vmapped table sweep + one masked drain-scatter; no scan.

    Charging (DESIGN.md §2, refined): a NACKing cache pays only the LR-CAM
    lookup (`tbl_lat`) — the probe is *filtered*, its L1 is never busied —
    and the issuer collects the parallel NACKs in one hop instead of
    serializing a wait per cache.  Only actual sharers charge flush time
    (theirs, and the issuer's wait for their writebacks to land at L2).
    This is the paper's scalability claim made literal: the rare remote
    path costs O(actual sharers), not O(n_caches)."""
    p = cfg.params
    n = cfg.n_caches
    addr32 = jnp.asarray(addr, jnp.int32)
    ptrs = jax.vmap(tables.lr_lookup, in_axes=(0, None))(st.lr, addr32)
    others = jnp.arange(n) != jnp.asarray(cid, jnp.int32)
    has = (ptrs >= 0) & others
    st, n_wb = b_drain(cfg, st, jnp.where(has, ptrs, INVALID), has)
    lr2 = jax.vmap(tables.lr_remove, in_axes=(0, None))(st.lr, addr32)
    pa2 = jax.vmap(tables.pa_insert, in_axes=(0, None))(st.pa, addr32)
    st = st._replace(lr=_mask_tree_rows(has, lr2, st.lr),
                     pa=_mask_tree_rows(has, pa2, st.pa))
    wait = jnp.sum(jnp.where(has, p.l2_lat + n_wb * p.wb_per_block, 0.0)) + 1.0
    c = st.counters
    nack = jnp.where(others & ~has, p.tbl_lat, 0.0)
    c = c._replace(cycles=(c.cycles + nack).at[cid].add(
                       p.probe_lat + p.l2_lat + wait),
                   probes=c.probes + jnp.float32(n - 1))
    return st._replace(counters=c)


def srsp_remote_acquire(cfg: ProtoConfig, st: Store, cid, addr, expect, new
                        ) -> Tuple[Store, jnp.ndarray]:
    """atomic_CAS_rem_acq_cmp under sRSP (§4.2)."""
    own_ptr = tables.lr_lookup(_get(st.lr, cid), addr)

    def same_cu(s):
        # §4.2: local sharer on the same CU — both use this L1; no promotion,
        # just make the releases globally ordered and CAS at L2.
        s, _ = drain_fifo(cfg, s, cid, own_ptr)
        lr_c = tables.lr_remove(_get(s.lr, cid), addr)
        s = s._replace(lr=_set(s.lr, cid, lr_c))
        return _atomic_l2(cfg, s, cid, addr, expect, new, True)

    def cross_cu(s):
        s = _probe_and_selective_flush(cfg, s, cid, addr)
        s = invalidate_cache(cfg, s, cid)          # own global-acquire part
        return _atomic_l2(cfg, s, cid, addr, expect, new, True)

    st, old = lax.cond(own_ptr >= 0, same_cu, cross_cu, st)
    c = st.counters
    return st._replace(counters=c._replace(remote_syncs=c.remote_syncs + 1.0)), old


def srsp_remote_release(cfg: ProtoConfig, st: Store, cid, addr, val) -> Store:
    """atomic_ST_rem_rel_cmp under sRSP (§4.3): flush own cache, ST at L2,
    broadcast selective-invalidate(addr) -> every PA-TBL records addr.

    The broadcast's acks are collected in parallel (one hop for the
    issuer); each receiving cache pays only the PA-CAM insert (`tbl_lat`)
    — O(1) per cache, O(actual contention) for the issuer (DESIGN.md §2)."""
    p = cfg.params
    st, _ = drain_fifo_all(cfg, st, cid)
    st, _ = _atomic_l2(cfg, st, cid, addr, 0, val, False)
    pa = jax.vmap(tables.pa_insert, in_axes=(0, None))(
        st.pa, jnp.asarray(addr, jnp.int32))
    st = st._replace(pa=pa)
    c = st.counters
    others = jnp.arange(cfg.n_caches) != jnp.asarray(cid, jnp.int32)
    recv = jnp.where(others, p.tbl_lat, 0.0)
    c = c._replace(cycles=(c.cycles + recv).at[cid].add(p.probe_lat + 1.0),
                   probes=c.probes + jnp.float32(cfg.n_caches),
                   remote_syncs=c.remote_syncs + 1.0)
    return st._replace(counters=c)


def rsp_remote_acquire(cfg: ProtoConfig, st: Store, cid, addr, expect, new
                       ) -> Tuple[Store, jnp.ndarray]:
    """Original RSP (§3): promote by flushing EVERY L1 — cost scales with the
    number of caches.  The caller then invalidates its own L1 and CASes at
    L2.  The flush-all is one batched drain-scatter instead of a scan."""
    p = cfg.params
    n = cfg.n_caches
    st, n_wb = b_drain(cfg, st, jnp.full((n,), _DRAIN_ALL),
                       jnp.ones((n,), bool))
    wait = jnp.sum(p.l2_lat + n_wb * p.wb_per_block)  # serialized at L2 port
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.probe_lat + wait),
                   probes=c.probes + jnp.float32(n - 1))
    st = st._replace(counters=c)
    st = invalidate_cache(cfg, st, cid)
    st, old = _atomic_l2(cfg, st, cid, addr, expect, new, True)
    c = st.counters
    return st._replace(counters=c._replace(remote_syncs=c.remote_syncs + 1.0)), old


def rsp_remote_release(cfg: ProtoConfig, st: Store, cid, addr, val) -> Store:
    """Original RSP: flush own, ST at L2, then INVALIDATE every L1 (flush-all
    + flash-invalidate each — the unscalable part)."""
    p = cfg.params
    n = cfg.n_caches
    st, _ = drain_fifo_all(cfg, st, cid)
    st, _ = _atomic_l2(cfg, st, cid, addr, 0, val, False)
    st = b_invalidate(cfg, st, jnp.ones((n,), bool))
    wait = jnp.float32(n) * p.l2_lat  # ack per cache through L2
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.probe_lat + wait),
                   probes=c.probes + jnp.float32(n),
                   remote_syncs=c.remote_syncs + 1.0)
    return st._replace(counters=c)


# --------------------------------------------------------------------------
# batched remote twins — address-disjoint remote ops in one masked round
# --------------------------------------------------------------------------

def srsp_remote_acquire_b(cfg: ProtoConfig, st: Store, active, addrs, expect,
                          new) -> Tuple[Store, jnp.ndarray]:
    """Masked multi-issuer twin of `srsp_remote_acquire` (DESIGN.md §9).

    One sRSP remote acquire per active lane in a single set of masked
    array stages: all probe rounds share ONE vmapped LR sweep (an
    [n_caches, n_lanes] lookup matrix) and all selective flushes merge
    into one drain-scatter, instead of a serialized scan per issuer.

    Bitwise-equal to serializing the active lanes in ascending order iff
    the batch is **address-disjoint** (the caller's obligation, enforced
    by the harness co-scheduling rule): active addrs pairwise distinct,
    no cache holds LR state or dirty words for more than one batch
    address, and no batch issuer holds LR state or dirty words for
    another issuer's address.  A one-hot batch is trivially
    address-disjoint and equals the scalar op exactly
    (tests/test_ops.py)."""
    p = cfg.params
    n = cfg.n_caches
    active = jnp.asarray(active, bool)
    addrs32 = jnp.asarray(addrs, jnp.int32)
    lanes = jnp.arange(n, dtype=jnp.int32)

    # §4.2 fork, per lane: a local sharer on the same CU skips promotion
    own_ptr = jax.vmap(tables.lr_lookup)(st.lr, addrs32)
    same = active & (own_ptr >= 0)
    cross = active & (own_ptr < 0)

    # same-CU lanes: make own releases globally ordered, then CAS at L2
    st, _ = b_drain(cfg, st, jnp.where(same, own_ptr, INVALID), same)
    lr_rm = jax.vmap(tables.lr_remove)(st.lr, addrs32)
    st = st._replace(lr=_mask_tree_rows(same, lr_rm, st.lr))

    # cross-CU lanes: one probe round for the whole batch
    ptrs = jax.vmap(lambda t: jax.vmap(
        lambda a: tables.lr_lookup(t, a))(addrs32))(st.lr)   # [cache, lane]
    probed = cross[None, :] & (lanes[:, None] != lanes[None, :])
    has = (ptrs >= 0) & probed
    sharer = jnp.any(has, axis=1)
    drain_pos = jnp.max(jnp.where(has, ptrs, INVALID), axis=1)
    st, n_wb = b_drain(cfg, st, jnp.where(sharer, drain_pos, INVALID), sharer)
    # move each sharer's (unique, under disjointness) probed addr LR -> PA
    shared_addr = addrs32[jnp.argmax(has, axis=1)]
    lr2 = jax.vmap(tables.lr_remove)(st.lr, shared_addr)
    pa2 = jax.vmap(tables.pa_insert)(st.pa, shared_addr)
    st = st._replace(lr=_mask_tree_rows(sharer, lr2, st.lr),
                     pa=_mask_tree_rows(sharer, pa2, st.pa))
    # charging (DESIGN.md §2): a NACKing cache pays one CAM lookup per
    # probe it filtered; each issuer waits for its own sharers only
    nack = jnp.sum((probed & ~has).astype(jnp.float32), axis=1) * p.tbl_lat
    wait = jnp.sum(jnp.where(has, (p.l2_lat + n_wb * p.wb_per_block)[:, None],
                             0.0), axis=0) + 1.0
    c = st.counters
    c = c._replace(
        cycles=c.cycles + nack
        + jnp.where(cross, p.probe_lat + p.l2_lat + wait, 0.0),
        probes=c.probes
        + jnp.sum(cross.astype(jnp.float32)) * jnp.float32(n - 1))
    st = st._replace(counters=c)

    # own global-acquire part for promoting lanes, then CAS at L2 for all
    st = b_invalidate(cfg, st, cross)
    st, old = b_atomic_l2(cfg, st, active, addrs32, expect, new, True)
    c = st.counters
    return st._replace(counters=c._replace(
        remote_syncs=c.remote_syncs
        + jnp.sum(active.astype(jnp.float32)))), old


def srsp_remote_release_b(cfg: ProtoConfig, st: Store, active, addrs,
                          vals) -> Store:
    """Masked multi-issuer twin of `srsp_remote_release` (DESIGN.md §9):
    all active lanes flush their own caches in one drain-scatter and ST at
    L2 in one masked atomic; the selective-invalidate broadcasts run as an
    ascending-lane scan (PA ages are insertion-order sensitive), matching
    the serialized order exactly.  Same address-disjointness obligation as
    `srsp_remote_acquire_b`."""
    p = cfg.params
    n = cfg.n_caches
    active = jnp.asarray(active, bool)
    addrs32 = jnp.asarray(addrs, jnp.int32)
    st, _ = b_drain(cfg, st, jnp.where(active, DRAIN_ALL, INVALID), active)
    st, _ = b_atomic_l2(cfg, st, active, addrs32, 0, vals, False)

    def ins(pa, xi):
        a, on = xi
        pa2 = jax.vmap(tables.pa_insert, in_axes=(0, None))(pa, a)
        return jax.tree.map(lambda nw, od: jnp.where(on, nw, od), pa2, pa), None

    pa, _ = lax.scan(ins, st.pa, (addrs32, active))
    st = st._replace(pa=pa)
    tot = jnp.sum(active.astype(jnp.float32))
    recv = (tot - active.astype(jnp.float32)) * p.tbl_lat
    c = st.counters
    c = c._replace(cycles=c.cycles + recv
                   + jnp.where(active, p.probe_lat + 1.0, 0.0),
                   probes=c.probes + tot * jnp.float32(n),
                   remote_syncs=c.remote_syncs + tot)
    return st._replace(counters=c)


# --------------------------------------------------------------------------
# protocol bundles
# --------------------------------------------------------------------------

_DEPRECATION_WARNED: set = set()   # one warning per legacy name per process


def _warn_deprecated(old: str, new: str) -> None:
    if old not in _DEPRECATION_WARNED:
        _DEPRECATION_WARNED.add(old)
        warnings.warn(
            f"Protocol.{old} is deprecated; use Protocol.{new} or the "
            f"scope-parametric surface in repro.core.ops "
            f"(acquire/release(..., scope=LOCAL|REMOTE|GLOBAL))",
            DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True)
class Protocol:
    """A registered scope-parametric op table (DESIGN.md §9).

    The paper's interface is an ISA of *scoped* atomics
    (`atomic_*_loc/rem/glob`, §2.1); a Protocol is one translation of
    that ISA onto the memory system — per scope, an acquire/release pair
    in two forms: a **masked multi-agent** op (`*_b`, active-mask
    signature — what both schedulers and `repro.core.ops` dispatch into)
    and the scalar single-cache reference the protocol unit tests pin
    against.  The mapping is the protocol's whole identity: `global`
    realizes even LOCAL-scope requests as heavyweight global sync
    (the paper's baseline), `local` realizes even REMOTE-scope requests
    as unsafe local sync (the staleness demo), and rsp/srsp differ only
    in their REMOTE realization (flush-everyone vs selective promotion).

    Capability declaration: `acquire_rem_b`/`release_rem_b` are the
    *batched address-disjoint remote twins*.  A protocol that carries
    them (`remote_batchable`) lets the harness co-schedule
    non-conflicting remote turns in one trip; protocols whose remote op
    inherently touches every cache (original RSP) declare None and their
    remote turns serialize, which is exactly the paper's scalability
    distinction surfacing as an API capability.

    Instances are looked up by name through the registry
    (`get_protocol` / `protocols()`); `register_protocol` adds one.
    Derived (e.g. fault-injected) protocols come from
    `workloads.faults.derive` and stay unregistered.

    The pre-redesign `owner_*`/`thief_*` attribute names remain as
    deprecation shims (one `DeprecationWarning` per name)."""
    name: str
    # local (work-group) scope — the cheap common-case ops
    acquire_loc_b: callable   # (cfg, st, active, addrs, expect, new) -> (st, old)
    release_loc_b: callable   # (cfg, st, active, addrs, vals) -> st
    acquire_loc: callable     # (cfg, st, cid, addr, expect, new) -> (st, old)
    release_loc: callable     # (cfg, st, cid, addr, val) -> st
    # remote scope — the rare cross-agent ops (scalar = serializing ref)
    acquire_rem: callable
    release_rem: callable
    # global (device) scope — the heavyweight everyone-pays ops
    acquire_glob_b: callable
    release_glob_b: callable
    acquire_glob: callable
    release_glob: callable
    # batched address-disjoint remote twins (capability; None = cannot)
    acquire_rem_b: callable = None
    release_rem_b: callable = None
    # crash-recovery drain (capability; None = dead holders never recover):
    # (cfg, st, mask) -> st — reclaim dirty words, force-release leased
    # sync words, invalidate LR/PA of every masked (dead) cache.
    recover_b: callable = None
    # crash fault injection (faults.crash_holding_lock): (victim, at) —
    # once cycles[victim] >= at, the victim's *synchronization*
    # instructions (and their lease bookkeeping) stop executing, modeling
    # death mid-turn inside a critical section: the lock stays held, the
    # turn's data writes stay stranded dirty in its L1.  None = healthy.
    crash_gate: tuple = None

    @property
    def remote_batchable(self) -> bool:
        """True when the protocol can run address-disjoint remote ops of
        several agents in one masked round (DESIGN.md §9)."""
        return self.acquire_rem_b is not None \
            and self.release_rem_b is not None

    # ---- deprecation shims (pre-redesign names) ----
    @property
    def owner_acquire(self):
        _warn_deprecated("owner_acquire", "acquire_loc")
        return self.acquire_loc

    @property
    def owner_release(self):
        _warn_deprecated("owner_release", "release_loc")
        return self.release_loc

    @property
    def thief_acquire(self):
        _warn_deprecated("thief_acquire", "acquire_rem")
        return self.acquire_rem

    @property
    def thief_release(self):
        _warn_deprecated("thief_release", "release_rem")
        return self.release_rem

    @property
    def owner_acquire_b(self):
        _warn_deprecated("owner_acquire_b", "acquire_loc_b")
        return self.acquire_loc_b

    @property
    def owner_release_b(self):
        _warn_deprecated("owner_release_b", "release_loc_b")
        return self.release_loc_b


class UnknownNameError(KeyError, ValueError):
    """Registry miss.  Subclasses BOTH KeyError (it is a mapping miss)
    and ValueError (what the pre-registry `runner()`/`WorkStealSim`
    checks raised), so existing handlers of either keep working."""


class Registry(dict):
    """name -> object mapping whose misses name every registered key —
    the `PROTOCOLS[...]`-style bare KeyError replacement (ISSUE 4)."""

    def __init__(self, kind: str):
        super().__init__()
        self.kind = kind

    def __missing__(self, key):
        raise UnknownNameError(f"unknown {self.kind} {key!r}; "
                               f"registered: {sorted(self)}")


# The protocol registry.  Indexing an unknown name raises with the list
# of registered names; `PROTOCOLS` stays importable for existing callers.
PROTOCOLS = Registry("protocol")


def register_protocol(proto: Protocol) -> Protocol:
    """Register `proto` under its name (usable as a decorator-style
    wrapper: ``SRSP = register_protocol(Protocol(...))``)."""
    PROTOCOLS[proto.name] = proto
    return proto


def protocols() -> tuple:
    """Names of every registered protocol, sorted."""
    return tuple(sorted(PROTOCOLS))


def get_protocol(name: str) -> Protocol:
    """Registered protocol by name; unknown names raise with the
    registered list."""
    return PROTOCOLS[name]


SRSP = register_protocol(Protocol(
    name="srsp",
    acquire_loc_b=local_acquire_b, release_loc_b=local_release_b,
    acquire_loc=local_acquire, release_loc=local_release,
    acquire_rem=srsp_remote_acquire, release_rem=srsp_remote_release,
    acquire_glob_b=global_acquire_b, release_glob_b=global_release_b,
    acquire_glob=global_acquire, release_glob=global_release,
    acquire_rem_b=srsp_remote_acquire_b,
    release_rem_b=srsp_remote_release_b,
    recover_b=b_recover))

# Original RSP's remote promotion flushes/invalidates EVERY cache, so two
# remote turns never commute: no batched remote twin, by declaration.
RSP = register_protocol(Protocol(
    name="rsp",
    acquire_loc_b=local_acquire_b, release_loc_b=local_release_b,
    acquire_loc=local_acquire, release_loc=local_release,
    acquire_rem=rsp_remote_acquire, release_rem=rsp_remote_release,
    acquire_glob_b=global_acquire_b, release_glob_b=global_release_b,
    acquire_glob=global_acquire, release_glob=global_release,
    recover_b=b_recover))

# Baseline: every scope realized as global sync — remote twins are the
# plain masked global ops (trivially address-disjoint-batchable).
GLOBAL = register_protocol(Protocol(
    name="global",
    acquire_loc_b=global_acquire_b, release_loc_b=global_release_b,
    acquire_loc=global_acquire, release_loc=global_release,
    acquire_rem=global_acquire, release_rem=global_release,
    acquire_glob_b=global_acquire_b, release_glob_b=global_release_b,
    acquire_glob=global_acquire, release_glob=global_release,
    acquire_rem_b=global_acquire_b, release_rem_b=global_release_b,
    recover_b=b_recover))

# NOT remote-safe — realizes REMOTE scope as local sync (staleness demo).
LOCAL_ONLY = register_protocol(Protocol(
    name="local",
    acquire_loc_b=local_acquire_b, release_loc_b=local_release_b,
    acquire_loc=local_acquire, release_loc=local_release,
    acquire_rem=local_acquire, release_rem=local_release,
    acquire_glob_b=global_acquire_b, release_glob_b=global_release_b,
    acquire_glob=global_acquire, release_glob=global_release,
    acquire_rem_b=local_acquire_b, release_rem_b=local_release_b,
    recover_b=b_recover))
