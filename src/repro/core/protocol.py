"""Functional model of the sRSP / RSP scoped-synchronization protocols (paper §2–4).

The memory system is modeled at word granularity over a shared L2 (the
global synchronization point) and N private L1 caches, exactly the
write-combining, no-allocate hierarchy of the paper's Table 1:

    Store.l2      [n_words]            word values at the L2 sync point
    Store.l1      [n_caches, n_words]  per-cache cached word values
    Store.wvalid  [n_caches, n_words]  local copy is readable
    Store.wdirty  [n_caches, n_words]  local copy not yet written back
    Store.fifo    batched SFifo        dirty-block FIFO  (QuickRelease)
    Store.lr      batched LRTbl        sRSP local-release table
    Store.pa      batched PATbl        sRSP promoted-acquire table

All operations are pure `(store, ...) -> (store', ...)` functions and fully
jittable; the cost model charges cycles/L2-transactions as a side channel in
`store.counters`.  Stale data is *really modeled*: an L1 may hold an old
copy of a word while L2 has moved on — a protocol bug shows up as a wrong
value read by a work-stealer, which the integration tests catch end-to-end.

Invariant maintained (checked by property tests): every dirty word's block
is present in that cache's sFIFO, so a FIFO drain is a complete flush.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import sfifo, tables
from repro.core.costmodel import CostParams, Counters, make_counters

INVALID = jnp.int32(-1)


@dataclasses.dataclass(frozen=True)
class ProtoConfig:
    n_caches: int
    n_words: int
    block_words: int = 16      # 64B block / 4B word (Table 1)
    fifo_cap: int = 16         # L1 sFIFO entries (Table 1)
    lr_cap: int = 8
    pa_cap: int = 8
    params: CostParams = dataclasses.field(default_factory=CostParams)

    @property
    def n_blocks(self) -> int:
        return (self.n_words + self.block_words - 1) // self.block_words


class Store(NamedTuple):
    l2: jnp.ndarray
    l1: jnp.ndarray
    wvalid: jnp.ndarray
    wdirty: jnp.ndarray
    fifo: sfifo.SFifo      # leaves have leading [n_caches]
    lr: tables.LRTbl
    pa: tables.PATbl
    counters: Counters


def make_store(cfg: ProtoConfig) -> Store:
    n, w = cfg.n_caches, cfg.n_words
    stack = lambda t: jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), t)
    return Store(
        l2=jnp.zeros((w,), jnp.int32),
        l1=jnp.zeros((n, w), jnp.int32),
        wvalid=jnp.zeros((n, w), bool),
        wdirty=jnp.zeros((n, w), bool),
        fifo=stack(sfifo.make(cfg.fifo_cap)),
        lr=stack(tables.lr_make(cfg.lr_cap)),
        pa=stack(tables.pa_make(cfg.pa_cap)),
        counters=make_counters(n),
    )


# --------------------------------------------------------------------------
# batched sub-structure helpers
# --------------------------------------------------------------------------

def _get(tree, cid):
    return jax.tree.map(lambda x: x[cid], tree)


def _set(tree, cid, sub):
    return jax.tree.map(lambda b, s: b.at[cid].set(s), tree, sub)


def _mask_tree(pred, new, old):
    """Select `new` where pred else `old` (same structure)."""
    return jax.tree.map(lambda n, o: jnp.where(pred, n, o), new, old)


def _blk(cfg: ProtoConfig, addr):
    return addr // cfg.block_words


# --------------------------------------------------------------------------
# block writeback and FIFO drains  (önbellek-temizleme machinery, §2.2)
# --------------------------------------------------------------------------

def writeback_block(cfg: ProtoConfig, st: Store, cid, b, guard=True
                    ) -> Tuple[Store, jnp.ndarray]:
    """Write back the dirty words of block `b` of cache `cid` to L2.

    Returns (store', did_wb) where did_wb is 1.0 if any word moved.
    With guard=False or b<0 this is a no-op (used in padded scans).
    """
    W = cfg.block_words
    start = jnp.clip(jnp.asarray(b, jnp.int32), 0) * W
    guard = jnp.asarray(guard, bool) & (jnp.asarray(b, jnp.int32) >= 0)
    l1_row = st.l1[cid]
    dirty_row = st.wdirty[cid]
    l1_blk = lax.dynamic_slice(l1_row, (start,), (W,))
    dirty_blk = lax.dynamic_slice(dirty_row, (start,), (W,))
    sel = dirty_blk & guard
    l2_blk = lax.dynamic_slice(st.l2, (start,), (W,))
    l2 = lax.dynamic_update_slice(st.l2, jnp.where(sel, l1_blk, l2_blk), (start,))
    new_dirty = lax.dynamic_update_slice(dirty_row, dirty_blk & ~sel, (start,))
    wdirty = st.wdirty.at[cid].set(new_dirty)
    did = jnp.any(sel).astype(jnp.float32)
    c = st.counters
    c = c._replace(l2_accesses=c.l2_accesses + did, wb_blocks=c.wb_blocks + did)
    return st._replace(l2=l2, wdirty=wdirty, counters=c), did


def drain_fifo(cfg: ProtoConfig, st: Store, cid, pos) -> Tuple[Store, jnp.ndarray]:
    """Selective flush: drain cache `cid`'s sFIFO up to seq `pos` (§4.2 step 3),
    writing each drained block back to L2.  pos<0 drains nothing;
    pos=+inf (use drain_fifo_all) drains everything.

    Returns (store', n_blocks_written)."""
    f = _get(st.fifo, cid)
    f, drained, _ = sfifo.drain_upto(f, pos)
    st = st._replace(fifo=_set(st.fifo, cid, f))

    def body(carry, b):
        s = carry
        s, did = writeback_block(cfg, s, cid, b)
        return s, did

    st, dids = lax.scan(body, st, drained)
    n_wb = jnp.sum(dids)
    # victim cache busy: handshake + pipelined writebacks
    p = cfg.params
    cyc = p.l2_lat + n_wb * p.wb_per_block
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(cyc))
    return st._replace(counters=c), n_wb


def drain_fifo_all(cfg: ProtoConfig, st: Store, cid) -> Tuple[Store, jnp.ndarray]:
    return drain_fifo(cfg, st, cid, jnp.int32(2**30))


def invalidate_cache(cfg: ProtoConfig, st: Store, cid) -> Store:
    """Whole-cache invalidate: flush dirty first (§2.2), flash-invalidate,
    clear LR-TBL and PA-TBL (§4.4)."""
    st, _ = drain_fifo_all(cfg, st, cid)
    wvalid = st.wvalid.at[cid].set(jnp.zeros((cfg.n_words,), bool))
    lr = _set(st.lr, cid, tables.lr_clear(_get(st.lr, cid)))
    pa = _set(st.pa, cid, tables.pa_clear(_get(st.pa, cid)))
    p = cfg.params
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.inv_flash),
                   inv_full=c.inv_full + 1.0,
                   inv_per_cache=c.inv_per_cache.at[cid].add(1.0))
    return st._replace(wvalid=wvalid, lr=lr, pa=pa, counters=c)


# --------------------------------------------------------------------------
# plain loads / stores through the cache
# --------------------------------------------------------------------------

def load(cfg: ProtoConfig, st: Store, cid, addr) -> Tuple[Store, jnp.ndarray]:
    """Ordinary read.  L1 hit or fill-from-L2 (read-allocate)."""
    hit = st.wvalid[cid, addr]
    val = jnp.where(hit, st.l1[cid, addr], st.l2[addr])
    l1 = st.l1.at[cid, addr].set(val)
    wvalid = st.wvalid.at[cid, addr].set(True)
    p = cfg.params
    c = st.counters
    c = c._replace(
        cycles=c.cycles.at[cid].add(jnp.where(hit, p.l1_lat, p.l1_lat + p.l2_lat)),
        l1_hits=c.l1_hits + hit.astype(jnp.float32),
        l1_misses=c.l1_misses + (~hit).astype(jnp.float32),
        l2_accesses=c.l2_accesses + (~hit).astype(jnp.float32),
    )
    return st._replace(l1=l1, wvalid=wvalid, counters=c), val


def store_word(cfg: ProtoConfig, st: Store, cid, addr, val, *, force_tail=False,
               guard=True) -> Tuple[Store, jnp.ndarray]:
    """Ordinary write (write-combining, no-allocate): update local copy, mark
    dirty, record the block in the sFIFO.  Capacity eviction writes the
    oldest block back (§2.2).  Returns (store', fifo_pos_of_block)."""
    guard = jnp.asarray(guard, bool)
    addr = jnp.asarray(addr, jnp.int32)
    l1 = st.l1.at[cid, addr].set(jnp.where(guard, jnp.asarray(val, jnp.int32),
                                           st.l1[cid, addr]))
    wvalid = st.wvalid.at[cid, addr].set(st.wvalid[cid, addr] | guard)
    wdirty = st.wdirty.at[cid, addr].set(st.wdirty[cid, addr] | guard)
    st = st._replace(l1=l1, wvalid=wvalid, wdirty=wdirty)

    f = _get(st.fifo, cid)
    f2, evicted, pos = sfifo.push(f, _blk(cfg, addr), force_tail)
    f = _mask_tree(guard, f2, f)
    evicted = jnp.where(guard, evicted, INVALID)
    st = st._replace(fifo=_set(st.fifo, cid, f))
    st, n_evwb = writeback_block(cfg, st, cid, evicted, guard=evicted >= 0)
    p = cfg.params
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(
        jnp.where(guard, p.l1_lat + n_evwb * p.wb_per_block, 0.0)))
    return st._replace(counters=c), pos


# --------------------------------------------------------------------------
# atomics
# --------------------------------------------------------------------------

def _atomic_l1(cfg, st: Store, cid, addr, expect, new, is_cas
               ) -> Tuple[Store, jnp.ndarray]:
    """Atomic executed at the L1 (local scope). Returns (store', old_value)."""
    st, cur = load(cfg, st, cid, addr)
    success = jnp.where(is_cas, cur == expect, True)
    st, _ = store_word(cfg, st, cid, addr, jnp.where(success, new, cur),
                       guard=success)
    return st, cur


def _atomic_l2(cfg, st: Store, cid, addr, expect, new, is_cas
               ) -> Tuple[Store, jnp.ndarray]:
    """Atomic executed at the L2 (global sync point). Returns (store', old)."""
    cur = st.l2[addr]
    success = jnp.where(is_cas, cur == expect, True)
    l2 = st.l2.at[addr].set(jnp.where(success, new, cur))
    # local copy of this word is no longer authoritative
    wvalid = st.wvalid.at[cid, addr].set(False)
    wdirty = st.wdirty.at[cid, addr].set(False)
    p = cfg.params
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.l2_lat),
                   l2_accesses=c.l2_accesses + 1.0)
    return st._replace(l2=l2, wvalid=wvalid, wdirty=wdirty, counters=c), cur


# --------------------------------------------------------------------------
# scoped synchronization — local (work-group) scope, §4.1 / §4.4
# --------------------------------------------------------------------------

def local_release(cfg: ProtoConfig, st: Store, cid, addr, val) -> Store:
    """atomic_ST_rel_wg: release at local scope.  Pushes the sync block to the
    sFIFO tail, records (addr -> pos) in the LR-TBL, atomic executes in L1."""
    st, pos = store_word(cfg, st, cid, addr, val, force_tail=True)
    lr = _get(st.lr, cid)
    lr, ev_addr, ev_ptr = tables.lr_insert(lr, addr, pos)
    st = st._replace(lr=_set(st.lr, cid, lr))
    # conservative overflow policy: an evicted LR record forces a drain up to
    # its recorded position so no release is silently lost (DESIGN.md §2)
    st, _ = drain_fifo(cfg, st, cid, jnp.where(ev_addr >= 0, ev_ptr, INVALID))
    p = cfg.params
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.tbl_lat),
                   local_syncs=c.local_syncs + 1.0)
    return st._replace(counters=c)


def local_acquire(cfg: ProtoConfig, st: Store, cid, addr, expect, new
                  ) -> Tuple[Store, jnp.ndarray]:
    """atomic_CAS_acq_wg: acquire at local scope (§4.4).  If the PA-TBL holds
    `addr` the acquire is promoted: full invalidate + CAS at L2.  Otherwise a
    cheap L1 CAS."""
    promote = tables.pa_contains(_get(st.pa, cid), addr)

    def promoted(s):
        s = invalidate_cache(cfg, s, cid)          # drains dirty, clears tables
        s, old = _atomic_l2(cfg, s, cid, addr, expect, new, True)
        c = s.counters
        c = c._replace(promotions=c.promotions + 1.0)
        return s._replace(counters=c), old

    def normal(s):
        return _atomic_l1(cfg, s, cid, addr, expect, new, True)

    st, old = lax.cond(promote, promoted, normal, st)
    p = cfg.params
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.tbl_lat),
                   local_syncs=c.local_syncs + 1.0)
    return st._replace(counters=c), old


# --------------------------------------------------------------------------
# global (device/cmp) scope — the heavyweight ops used by Baseline/Steal-only
# --------------------------------------------------------------------------

def global_release(cfg: ProtoConfig, st: Store, cid, addr, val) -> Store:
    st, _ = drain_fifo_all(cfg, st, cid)
    st, _ = _atomic_l2(cfg, st, cid, addr, 0, val, False)
    c = st.counters
    return st._replace(counters=c._replace(global_syncs=c.global_syncs + 1.0))


def global_acquire(cfg: ProtoConfig, st: Store, cid, addr, expect, new
                   ) -> Tuple[Store, jnp.ndarray]:
    st = invalidate_cache(cfg, st, cid)
    st, old = _atomic_l2(cfg, st, cid, addr, expect, new, True)
    c = st.counters
    return st._replace(counters=c._replace(global_syncs=c.global_syncs + 1.0)), old


# --------------------------------------------------------------------------
# remote scope promotion — sRSP (§4.2, §4.3) and original RSP (§3) variants
# --------------------------------------------------------------------------

def _probe_and_selective_flush(cfg: ProtoConfig, st: Store, cid, addr) -> Store:
    """Broadcast a selective-flush(addr) probe via L2 to every L1 (§4.2 step 2).
    Only caches with an LR-TBL entry for addr drain — up to the recorded
    position — then move addr into their PA-TBL.  Everyone else NACKs."""
    p = cfg.params
    n = cfg.n_caches

    def body(carry, j):
        s, wait = carry
        lr_j = _get(s.lr, j)
        ptr = tables.lr_lookup(lr_j, addr)
        has = (ptr >= 0) & (j != cid)
        s, n_wb = drain_fifo(cfg, s, j, jnp.where(has, ptr, INVALID))
        lr_j2 = tables.lr_remove(lr_j, addr)
        s = s._replace(lr=_set(s.lr, j, _mask_tree(has, lr_j2, _get(s.lr, j))))
        pa_j = _get(s.pa, j)
        pa_j2 = tables.pa_insert(pa_j, addr)
        s = s._replace(pa=_set(s.pa, j, _mask_tree(has, pa_j2, pa_j)))
        wait = wait + jnp.where(has, p.l2_lat + n_wb * p.wb_per_block, 1.0)
        return (s, wait), None

    (st, wait), _ = lax.scan(body, (st, jnp.float32(0.0)), jnp.arange(n))
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.probe_lat + p.l2_lat + wait),
                   probes=c.probes + jnp.float32(n - 1))
    return st._replace(counters=c)


def srsp_remote_acquire(cfg: ProtoConfig, st: Store, cid, addr, expect, new
                        ) -> Tuple[Store, jnp.ndarray]:
    """atomic_CAS_rem_acq_cmp under sRSP (§4.2)."""
    own_ptr = tables.lr_lookup(_get(st.lr, cid), addr)

    def same_cu(s):
        # §4.2: local sharer on the same CU — both use this L1; no promotion,
        # just make the releases globally ordered and CAS at L2.
        s, _ = drain_fifo(cfg, s, cid, own_ptr)
        lr_c = tables.lr_remove(_get(s.lr, cid), addr)
        s = s._replace(lr=_set(s.lr, cid, lr_c))
        return _atomic_l2(cfg, s, cid, addr, expect, new, True)

    def cross_cu(s):
        s = _probe_and_selective_flush(cfg, s, cid, addr)
        s = invalidate_cache(cfg, s, cid)          # own global-acquire part
        return _atomic_l2(cfg, s, cid, addr, expect, new, True)

    st, old = lax.cond(own_ptr >= 0, same_cu, cross_cu, st)
    c = st.counters
    return st._replace(counters=c._replace(remote_syncs=c.remote_syncs + 1.0)), old


def srsp_remote_release(cfg: ProtoConfig, st: Store, cid, addr, val) -> Store:
    """atomic_ST_rem_rel_cmp under sRSP (§4.3): flush own cache, ST at L2,
    broadcast selective-invalidate(addr) -> every PA-TBL records addr."""
    p = cfg.params
    st, _ = drain_fifo_all(cfg, st, cid)
    st, _ = _atomic_l2(cfg, st, cid, addr, 0, val, False)

    def body(s, j):
        pa_j = tables.pa_insert(_get(s.pa, j), addr)
        return s._replace(pa=_set(s.pa, j, pa_j)), None

    st, _ = lax.scan(body, st, jnp.arange(cfg.n_caches))
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.probe_lat + cfg.n_caches * 1.0),
                   probes=c.probes + jnp.float32(cfg.n_caches),
                   remote_syncs=c.remote_syncs + 1.0)
    return st._replace(counters=c)


def rsp_remote_acquire(cfg: ProtoConfig, st: Store, cid, addr, expect, new
                       ) -> Tuple[Store, jnp.ndarray]:
    """Original RSP (§3): promote by flushing EVERY L1 — cost scales with the
    number of caches.  The caller then invalidates its own L1 and CASes at L2."""
    p = cfg.params

    def body(carry, j):
        s, wait = carry
        s, n_wb = drain_fifo_all(cfg, s, j)
        wait = wait + p.l2_lat + n_wb * p.wb_per_block  # serialized at L2 port
        return (s, wait), None

    (st, wait), _ = lax.scan(body, (st, jnp.float32(0.0)), jnp.arange(cfg.n_caches))
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.probe_lat + wait),
                   probes=c.probes + jnp.float32(cfg.n_caches - 1))
    st = st._replace(counters=c)
    st = invalidate_cache(cfg, st, cid)
    st, old = _atomic_l2(cfg, st, cid, addr, expect, new, True)
    c = st.counters
    return st._replace(counters=c._replace(remote_syncs=c.remote_syncs + 1.0)), old


def rsp_remote_release(cfg: ProtoConfig, st: Store, cid, addr, val) -> Store:
    """Original RSP: flush own, ST at L2, then INVALIDATE every L1 (flush-all
    + flash-invalidate each — the unscalable part)."""
    p = cfg.params
    st, _ = drain_fifo_all(cfg, st, cid)
    st, _ = _atomic_l2(cfg, st, cid, addr, 0, val, False)

    def body(carry, j):
        s, wait = carry
        s = invalidate_cache(cfg, s, j)
        wait = wait + p.l2_lat  # ack per cache through L2
        return (s, wait), None

    (st, wait), _ = lax.scan(body, (st, jnp.float32(0.0)), jnp.arange(cfg.n_caches))
    c = st.counters
    c = c._replace(cycles=c.cycles.at[cid].add(p.probe_lat + wait),
                   probes=c.probes + jnp.float32(cfg.n_caches),
                   remote_syncs=c.remote_syncs + 1.0)
    return st._replace(counters=c)


# --------------------------------------------------------------------------
# protocol bundles
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Protocol:
    """The op table a scenario binds against (see worksteal.py)."""
    name: str
    owner_acquire: callable   # (cfg, st, cid, addr, expect, new) -> (st, old)
    owner_release: callable   # (cfg, st, cid, addr, val) -> st
    thief_acquire: callable
    thief_release: callable


SRSP = Protocol("srsp", local_acquire, local_release,
                srsp_remote_acquire, srsp_remote_release)
RSP = Protocol("rsp", local_acquire, local_release,
               rsp_remote_acquire, rsp_remote_release)
GLOBAL = Protocol("global", global_acquire, global_release,
                  global_acquire, global_release)
LOCAL_ONLY = Protocol("local", local_acquire, local_release,
                      local_acquire, local_release)  # NOT steal-safe — used to
                                                     # demonstrate staleness

PROTOCOLS = {p.name: p for p in (SRSP, RSP, GLOBAL, LOCAL_ONLY)}
