from repro.configs.base import (  # noqa: F401
    ModelConfig, MoECfg, MLACfg, SSMCfg, ShapeCfg, SHAPES,
)
