"""granite-moe-1b-a400m — MoE, 24L d=1024 16H (GQA kv=8) d_expert=512
vocab=49155, 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base.]"""
import dataclasses

from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64, tie_embeddings=True,
    moe=MoECfg(n_experts=32, top_k=8, d_expert=512),
    microbatch=64, optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab=512, head_dim=16,
    moe=MoECfg(n_experts=4, top_k=2, d_expert=64), dtype="float32",
)
