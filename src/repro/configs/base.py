"""Config schema: architectures and input-shape cells.

Every assigned architecture has a module ``repro/configs/<id>.py`` exposing
``CONFIG`` (exact published scale) and ``SMOKE`` (reduced same-family config
for CPU tests).  Input shapes are the four assigned cells; `applicable`
encodes the documented skips (DESIGN.md §4)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # dispatch groups (aligned with data shards -> communication-free
    # dispatch; the combine is the only cross-shard reduction). §Perf.
    dispatch_groups: int = 32


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str            # dense | moe | vlm | ssm_xlstm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0
    norm: str = "rmsnorm"   # rmsnorm | layernorm
    act: str = "swiglu"     # swiglu | gelu
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    attn_every: int = 0     # hybrid: shared attention applied every k layers
    enc_layers: int = 0     # encdec: encoder depth (n_layers = decoder depth)
    n_patches: int = 0      # vlm: image patch embeddings replacing a prefix
    mtp_heads: int = 0      # deepseek multi-token-prediction extra heads
    xlstm_pattern: str = "" # e.g. "msmsmsmsmsms" (m=mLSTM, s=sLSTM)
    # training knobs
    dtype: str = "bfloat16"
    microbatch: Optional[int] = None   # per train_4k cell; None = no accum
    optimizer: str = "adamw"           # adamw | adafactor
    # distribution knobs (hillclimbed in EXPERIMENTS.md §Perf)
    seq_parallel: bool = False         # Megatron-style SP on the residual
    remat_policy: str = "full"         # full | dots (selective)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> float:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        d, L = self.d_model, self.n_layers
        n = 2 * self.vocab * d if not self.tie_embeddings else self.vocab * d
        if self.family == "ssm_xlstm":
            # rough: mLSTM/sLSTM blocks ~ 8*d^2 per layer incl. up/down proj
            return n + L * 13 * d * d
        ff_mult0 = 3 if self.act == "swiglu" else 2
        if self.family == "hybrid" and self.ssm is not None:
            # Mamba2 layers + ONE shared attention+FFN block
            s = self.ssm
            di = s.expand * d
            h = di // s.head_dim
            per_mamba = d * (2 * di + 2 * s.n_groups * s.d_state + h) + di * d
            hd = self.hd
            shared = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                      + self.n_heads * hd * d + ff_mult0 * d * self.d_ff)
            return float(n + L * per_mamba + shared)
        per_layer = 0.0
        hd = self.hd
        if self.mla is not None:
            m = self.mla
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.qk_rope_head_dim)
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        else:
            per_layer += d * self.n_heads * hd          # wq
            per_layer += 2 * d * self.n_kv_heads * hd   # wk, wv
            per_layer += self.n_heads * hd * d          # wo
        ff_mult = 3 if self.act == "swiglu" else 2
        if self.moe is not None:
            mo = self.moe
            moe_layers = L - mo.first_k_dense
            per_layer_ff = mo.n_experts * ff_mult * d * mo.d_expert \
                + mo.n_shared * ff_mult * d * mo.d_expert + d * mo.n_experts
            n += moe_layers * per_layer_ff + mo.first_k_dense * ff_mult * d * self.d_ff
        else:
            n += L * ff_mult * d * self.d_ff
        n += L * per_layer
        if self.enc_layers:
            n += self.enc_layers * (per_layer + ff_mult * d * self.d_ff)
        return float(n)

    def active_param_count(self) -> float:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        ff_mult = 3 if self.act == "swiglu" else 2
        moe_layers = L - mo.first_k_dense
        all_experts = moe_layers * mo.n_experts * ff_mult * d * mo.d_expert
        active = moe_layers * mo.top_k * ff_mult * d * mo.d_expert
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

# pure full-attention archs skip long_500k (needs sub-quadratic sequence
# state; DESIGN.md §4) — SSM / hybrid archs run it.
LONG_CAPABLE_FAMILIES = {"ssm_xlstm", "hybrid"}


def applicable(cfg: ModelConfig, shape: ShapeCfg) -> bool:
    if shape.name == "long_500k":
        return cfg.family in LONG_CAPABLE_FAMILIES
    return True
