"""seamless-m4t-large-v2 — enc-dec, 24L enc + 24L dec, d=1024 16H (kv=16)
d_ff=8192 vocab=256206.  [arXiv:2308.11596.]
Modality frontend is a STUB: input_specs supplies precomputed frame
embeddings [B, S_enc, 1024]; encoder length = seq (train/prefill) or seq//8
(decode cells) — DESIGN.md §4."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, head_dim=64, norm="layernorm", act="gelu",
    microbatch=64, optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, head_dim=16, microbatch=None, dtype="float32",
)
