"""llava-next-mistral-7b — VLM, mistral-7b backbone: 32L d=4096 32H (GQA
kv=8) d_ff=14336 vocab=32000.  [hf:llava-hf/llava-v1.6-mistral-7b-hf.]
Modality frontend is a STUB: input_specs supplies precomputed patch
embeddings [B, 576, 1024] (anyres tiling NOT modeled — DESIGN.md §4)."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, head_dim=128, rope_theta=1e6, n_patches=576,
    microbatch=64, optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, n_patches=4, microbatch=None, dtype="float32",
)
