"""xlstm-125m — sLSTM + mLSTM blocks, 12L d=768 4H vocab=50304.
[arXiv:2405.04517; alternating m/s pattern.]  long_500k capable (O(1) state)."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm_xlstm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, xlstm_pattern="msmsmsmsmsms",
    microbatch=64, optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=2, vocab=512,
    xlstm_pattern="ms", dtype="float32",
)
