"""stablelm-12b — dense, 40L d=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-12b family; partial rotary 25%, LayerNorm.]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab=100352, head_dim=160, partial_rotary=0.25, norm="layernorm",
    act="swiglu", rope_theta=10000.0, microbatch=64, optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, microbatch=None, dtype="float32",
)
