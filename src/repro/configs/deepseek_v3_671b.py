"""deepseek-v3-671b — MoE+MLA, 61L d=7168 128H d_expert=2048 vocab=129280,
1 shared + 256 routed experts top-8, MLA latent KV, MTP depth 1, first 3
layers dense (d_ff=18432).  [arXiv:2412.19437.]
Trains with adafactor + FSDP + microbatch 8 (memory: EXPERIMENTS.md §Dry-run)."""
import dataclasses

from repro.configs.base import ModelConfig, MoECfg, MLACfg

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab=129280,
    moe=MoECfg(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
               first_k_dense=3, capacity_factor=1.25),
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
               qk_rope_head_dim=64, v_head_dim=128),
    mtp_heads=1, microbatch=32, optimizer="adafactor",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512,
    moe=MoECfg(n_experts=4, top_k=2, d_expert=32, n_shared=1, first_k_dense=1),
    mla=MLACfg(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
               qk_rope_head_dim=8, v_head_dim=16),
    dtype="float32",
)
