"""mistral-large-123b — dense, 88L d=12288 96H (GQA kv=8) d_ff=28672
vocab=32768.  [hf:mistralai/Mistral-Large-Instruct-2407; unverified.]
Memory-heavy: trains with adafactor + FSDP + microbatch 8."""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672,
    vocab=32768, head_dim=128, rope_theta=1e6,
    microbatch=32, optimizer="adafactor",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, microbatch=None, dtype="float32",
)
