"""qwen2.5-32b — dense, 64L d=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
[hf:Qwen/Qwen2.5 family; QKV bias, RMSNorm, SwiGLU, rope theta 1e6.]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
    vocab=152064, head_dim=128, qkv_bias=True, rope_theta=1e6,
    microbatch=64, optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=512, head_dim=16, microbatch=None, dtype="float32",
)
