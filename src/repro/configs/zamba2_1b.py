"""zamba2-1.2b — hybrid, 38 Mamba2 layers d=2048 + shared attention block
(32H, d_ff=8192) applied every 6 layers, ssm_state=64, vocab=32000.
[arXiv:2411.15242.]  long_500k capable (Mamba2 O(1) state; shared-attn KV
sharded over 'seqs')."""
import dataclasses

from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, head_dim=64, attn_every=6,
    ssm=SSMCfg(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
               chunk=128),
    microbatch=64, optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512, head_dim=16, attn_every=2,
    ssm=SSMCfg(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
               chunk=16),
    microbatch=None, dtype="float32",
)
