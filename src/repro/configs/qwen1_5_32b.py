"""qwen1.5-32b — dense MHA (kv=40), 64L d=5120 40H d_ff=27392 vocab=152064.
[hf:Qwen/Qwen1.5 family; QKV bias.]"""
import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
    vocab=152064, head_dim=128, qkv_bias=True, rope_theta=1e6,
    microbatch=64, optimizer="adamw",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=512, head_dim=16, microbatch=None, dtype="float32",
)
