"""KV directory — owner-local updates, rare cross-owner remote lookups.

Asymmetry shape: a shared hash directory of lock-protected buckets,
partitioned so bucket ``b`` is owned by agent ``b % n_agents``.  Owners
update their own buckets with local-scope synchronization (the hot
path); after an agent drains its own update quota it performs a few
*remote* lookups of buckets owned by others — the phase structure of a
serving tier where each worker mostly touches its own shard of a shared
KV/prefix-cache directory (`serve/engine.py`'s slot cache is the
n_agents=1 degenerate case) and occasionally resolves another worker's
entry.

Spec (DESIGN.md §7):
  * local turns: owner i round-robins over its own buckets — acquire
    bucket lock, read the value THROUGH the store (owner stale-read
    check), store value+delta and version+1, release.  Ownership
    partitions the directory, so local turns of distinct agents commute.
  * remote turn: lookup of a deterministic non-owned bucket — remote
    acquire, read version and value words, compare against bookkept
    ground truth, release.  New values are computed from bookkeeping,
    never from store reads, so a protocol bug changes *checked values*
    only, not the schedule.
  * fence: an agent goes remote only after its remaining
    ``upd_quota - upd_done`` local updates, each charging at least
    ``task_cost`` cycles — the work-steal ``rem`` bound, re-derived.
  * self-check: in-run version/value mismatches + post-run drained-L2
    audit of every bucket (lost-update detection).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import ops as O
from repro.core import protocol as P
from repro.core import tables
from repro.core.costmodel import CostParams
from repro.workloads import harness

VMAPPABLE = True


@dataclasses.dataclass(frozen=True)
class Config:
    n_agents: int = 8
    buckets_per_agent: int = 2
    updates_per_agent: int = 6   # seed-jittered by +0/1 in init_state
    lookups_per_agent: int = 2
    task_cost: float = 20.0      # compute cycles charged per update turn
    fifo_cap: int = 16
    lr_tbl: tables.TableGeometry = tables.LR_GEOMETRY
    pa_tbl: tables.TableGeometry = tables.PA_GEOMETRY
    params: CostParams = dataclasses.field(default_factory=CostParams)

    @property
    def n_buckets(self) -> int:
        return self.n_agents * self.buckets_per_agent

    @property
    def bstride(self) -> int:
        return 16   # lock / version / value in one block

    @property
    def n_words(self) -> int:
        return self.n_buckets * self.bstride

    def proto_cfg(self) -> P.ProtoConfig:
        return P.ProtoConfig(n_caches=self.n_agents, n_words=self.n_words,
                             fifo_cap=self.fifo_cap, lr_tbl=self.lr_tbl,
                             pa_tbl=self.pa_tbl, params=self.params)


class KVState(NamedTuple):
    store: P.Store
    upd_done: jnp.ndarray   # [n] i32 updates completed per agent
    look_done: jnp.ndarray  # [n] i32 lookups completed per agent
    upd_quota: jnp.ndarray  # [n] i32 per-agent (seed-jittered) update target
    ver: jnp.ndarray        # [n_buckets] i32 bookkeeping: true version
    val: jnp.ndarray        # [n_buckets] i32 bookkeeping: true value
    salt: jnp.ndarray       # [] i32 seed-derived delta/lookup salt
    check_fails: jnp.ndarray  # [] i32
    rounds: jnp.ndarray       # [] i32


def _max_events(cfg: Config) -> int:
    return cfg.n_agents * (cfg.updates_per_agent + 1
                           + cfg.lookups_per_agent) + 4 * cfg.n_agents


def _lanes(cfg: Config):
    return jnp.arange(cfg.n_agents, dtype=jnp.int32)


def _can_local(wl, s: KVState):
    return s.upd_done < s.upd_quota


def _can_remote(wl, s: KVState):
    return (s.upd_done >= s.upd_quota) \
        & (s.look_done < wl.cfg.lookups_per_agent)


def _remote_bound(wl, s: KVState):
    left = (s.upd_quota - s.upd_done).astype(jnp.float32)
    return jnp.maximum(left, 0.0) * wl.cfg.task_cost


def _live(wl, s: KVState):
    work = jnp.any(s.upd_done < s.upd_quota) \
        | jnp.any(s.look_done < wl.cfg.lookups_per_agent)
    return work & (s.rounds < _max_events(wl.cfg))


def _retire(wl, s: KVState, dead, *ops) -> KVState:
    """Elastic retirement (DESIGN.md §10): a dead owner stops owing
    updates and lookups — its buckets keep their bookkept ver/val ground
    truth, so the post-run drained-L2 audit still checks every committed
    update.  Bitwise identity when `dead` is all-False."""
    dead = jnp.asarray(dead, bool)
    return s._replace(
        upd_quota=jnp.where(dead, jnp.minimum(s.upd_quota, s.upd_done),
                            s.upd_quota),
        look_done=jnp.where(dead,
                            jnp.maximum(s.look_done,
                                        jnp.int32(wl.cfg.lookups_per_agent)),
                            s.look_done))


def _admit(wl, s: KVState, join, *ops) -> KVState:
    """Elastic (re-)admission: a joining owner owes one more update to
    its shard."""
    join = jnp.asarray(join, bool)
    return s._replace(
        upd_quota=jnp.where(join, s.upd_done + 1, s.upd_quota))


def _delta(lanes, upd_done, salt):
    return (lanes + 1) + jnp.mod(upd_done * 7 + salt, jnp.int32(5))


def _local_turn(wl, s: KVState, mask) -> KVState:
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    lanes = _lanes(cfg)
    nb = cfg.n_buckets

    # round-robin over own buckets: disjoint across agents by ownership
    b = lanes + jnp.mod(s.upd_done, jnp.int32(cfg.buckets_per_agent)) \
        * cfg.n_agents
    lockb = b * cfg.bstride
    delta = _delta(lanes, s.upd_done, s.salt)
    newval = s.val[b] + delta

    st = s.store
    st, _ = O.acquire(wl.proto, pc, st, mask, lockb, 0, 1, scope=O.LOCAL)
    st, vcur = O.load(pc, st, mask, lockb + 2)
    st, _ = O.store(pc, st, mask, lockb + 2, newval)
    st, _ = O.store(pc, st, mask, lockb + 1, s.ver[b] + 1)
    st = O.release(wl.proto, pc, st, mask, lockb, 0, scope=O.LOCAL)
    st = harness.charge(st, mask, cfg.task_cost)

    # owner stale-read check: the value read through the store must be
    # the bookkept one (integral, order-independent accumulation)
    fails = jnp.sum((mask & (vcur != s.val[b])).astype(jnp.int32))
    tgt = jnp.where(mask, b, nb)
    return KVState(
        store=st,
        upd_done=s.upd_done + mask.astype(jnp.int32),
        look_done=s.look_done,
        upd_quota=s.upd_quota,
        ver=s.ver.at[tgt].add(1, mode="drop"),
        val=s.val.at[tgt].add(delta, mode="drop"),
        salt=s.salt,
        check_fails=s.check_fails + fails,
        rounds=s.rounds + jnp.sum(mask.astype(jnp.int32)))


def _remote_turn(wl, s: KVState, wg) -> KVState:
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    nb = cfg.n_buckets
    do = _can_remote(wl, s)[wg]   # the scheduler's own predicate, in sync

    def lookup(s: KVState) -> KVState:
        t = jnp.mod(wg + 1 + s.look_done[wg] * 5 + s.salt, jnp.int32(nb))
        t = jnp.where(jnp.mod(t, cfg.n_agents) == wg,
                      jnp.mod(t + 1, jnp.int32(nb)), t)
        lockt = t * cfg.bstride
        st = s.store
        hot = harness.one_hot(cfg.n_agents, wg)
        st, old_v = O.acquire(wl.proto, pc, st, hot, lockt, 0, 1,
                              scope=O.REMOTE)
        old = old_v[wg]
        st, rv = P.load(pc, st, wg, lockt + 1)
        st, vv = P.load(pc, st, wg, lockt + 2)
        st = O.release(wl.proto, pc, st, hot, lockt, 0, scope=O.REMOTE)
        fails = (old != 0).astype(jnp.int32) \
            + (rv != s.ver[t]).astype(jnp.int32) \
            + (vv != s.val[t]).astype(jnp.int32)
        return KVState(
            store=st,
            upd_done=s.upd_done,
            look_done=s.look_done.at[wg].add(1),
            upd_quota=s.upd_quota,
            ver=s.ver, val=s.val, salt=s.salt,
            check_fails=s.check_fails + fails,
            rounds=s.rounds + 1)

    def idle(s: KVState) -> KVState:
        return s._replace(rounds=s.rounds + 1)

    return lax.cond(do, lookup, idle, s)


def build_workload(cfg: Config, proto: P.Protocol) -> harness.Workload:
    return harness.Workload(
        name="kv_directory", cfg=cfg, proto=proto, has_remote=True,
        can_local=_can_local, can_remote=_can_remote,
        local_turn=_local_turn, remote_turn=_remote_turn,
        remote_bound=_remote_bound, live=_live,
        retire=_retire, admit=_admit)


def init_state(wl, seed) -> KVState:
    cfg = wl.cfg
    lanes = _lanes(cfg)
    seed = jnp.asarray(seed, jnp.int32)
    quota = cfg.updates_per_agent + jnp.mod(seed * 17 + lanes * 11,
                                            jnp.int32(2))
    n = cfg.n_agents
    return KVState(
        store=P.make_store(cfg.proto_cfg()),
        upd_done=jnp.zeros((n,), jnp.int32),
        look_done=jnp.zeros((n,), jnp.int32),
        upd_quota=quota.astype(jnp.int32),
        ver=jnp.zeros((cfg.n_buckets,), jnp.int32),
        val=jnp.zeros((cfg.n_buckets,), jnp.int32),
        salt=jnp.mod(seed * 7919, jnp.int32(97)),
        check_fails=jnp.int32(0),
        rounds=jnp.int32(0))


def self_check(wl, final: KVState) -> dict:
    """In-run mismatches + drained-L2 per-bucket lost-update audit."""
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    fails = int(final.check_fails)
    done = bool(np.all(np.asarray(final.upd_done)
                       >= np.asarray(final.upd_quota))) and bool(
        np.all(np.asarray(final.look_done) >= cfg.lookups_per_agent))
    st = harness.drain_all(pc, final.store)
    l2 = np.asarray(st.l2).reshape(-1)
    ver = np.asarray(final.ver)
    val = np.asarray(final.val)
    for b in range(cfg.n_buckets):
        base = b * cfg.bstride
        fails += int(l2[base + 1] != ver[b]) + int(l2[base + 2] != val[b])
    return {"ok": fails == 0 and done, "check_fails": fails,
            "done": done, "events": int(final.rounds)}


def build(scenario: str, n_agents: int, seed: int = 0, *,
          proto: P.Protocol = None, **kw) -> harness.Bench:
    return harness.make_bench(Config(n_agents=n_agents, **kw),
                              build_workload, init_state, self_check,
                              scenario, seed, proto)
