"""Pluggable asymmetric-sharing workloads (DESIGN.md §7).

Every registered workload module implements the same contract:

  build(scenario, n_agents, seed=0, *, proto=None, **kw) -> Bench
      Bench(wl, state, ops, check): the harness Workload, a fresh initial
      state, extra scheduler operands, and a host-side self-check
      `check(final_state) -> {"ok": bool, "check_fails": int, ...}` that
      detects protocol bugs (lost updates, stale reads).  `proto`
      overrides the scenario's op table — fault injection for tests.
  VMAPPABLE: bool
      True when `init_state(wl, seed)` is pure jnp, so the sweep can
      stack replicas and run them in one compiled `run_batched_many`.
  init_state(wl, seed) -> state      (VMAPPABLE modules only)

Scenario names map onto the protocol tables exactly as the paper's
work-steal harness does: baseline→global-scope, scope_only→local-scope
(NOT remote-safe — the staleness demo), rsp→local+RSP promotion,
srsp→local+selective promotion.
"""
from __future__ import annotations

import importlib

_MODULES = {
    "worksteal": "repro.workloads.worksteal",
    "producer_consumer": "repro.workloads.producer_consumer",
    "producer_consumer_mc": "repro.workloads.producer_consumer_mc",
    "reader_lock": "repro.workloads.reader_lock",
    "kv_directory": "repro.workloads.kv_directory",
    "kv_serving": "repro.workloads.kv_serving",
}


def available():
    return sorted(_MODULES)


def get(name: str):
    """Return the registered workload module (lazy import)."""
    if name not in _MODULES:
        raise KeyError(f"unknown workload {name!r}; "
                       f"available: {available()}")
    return importlib.import_module(_MODULES[name])
