"""Reader-heavy lock — one hot local writer, many rare remote readers.

Asymmetry shape per *Asymmetry-aware Scalable Locking* (arXiv:2108.03355):
a single writer updates a lock-protected multi-word payload at high rate
with cheap local-scope synchronization; every other agent occasionally
remote-acquires the same lock to read the payload.  Unlike work-stealing
(many writers, roaming readers) the conflict object here is one global
hot line, so promotion traffic concentrates on a single LR/PA-TBL entry.

Spec (DESIGN.md §7):
  * local turns: the writer's seqlock-style publish — acquire own lock,
    store `writes_done+1` into every payload word, release; readers burn
    scratch turns in their own regions between reads.  Writer region and
    reader scratch regions are pairwise disjoint → local turns commute.
  * remote turn: reader remote-acquires the writer's lock, reads all
    payload words, releases.  The read is torn/stale-checked in-run:
    every payload word must equal every other AND equal the bookkept
    `writes_done` at the read's serial position (a correct remote acquire
    forces the writer's released stores to L2 and invalidates the
    reader's stale copies; a weakened one reads garbage).
  * fence: reader i's next read is at least `credit[i] · scratch_cost`
    cycles away; the writer never goes remote.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import ops as O
from repro.core import protocol as P
from repro.core import tables
from repro.core.costmodel import CostParams
from repro.workloads import harness

VMAPPABLE = True


@dataclasses.dataclass(frozen=True)
class Config:
    n_agents: int = 8
    n_writes: int = 10          # writer publishes this many versions
    reads_per_reader: int = 2
    gap: int = 3                # reader scratch turns before each read
    payload_w: int = 4          # payload words behind the lock
    scratch_cost: float = 20.0
    fifo_cap: int = 16
    lr_tbl: tables.TableGeometry = tables.LR_GEOMETRY
    pa_tbl: tables.TableGeometry = tables.PA_GEOMETRY
    params: CostParams = dataclasses.field(default_factory=CostParams)

    @property
    def stride(self) -> int:
        return 16

    @property
    def n_words(self) -> int:
        return self.n_agents * self.stride

    def proto_cfg(self) -> P.ProtoConfig:
        return P.ProtoConfig(n_caches=self.n_agents, n_words=self.n_words,
                             fifo_cap=self.fifo_cap, lr_tbl=self.lr_tbl,
                             pa_tbl=self.pa_tbl, params=self.params)


class RLState(NamedTuple):
    store: P.Store
    writes_done: jnp.ndarray  # [] i32 bookkeeping: versions published
    reads_done: jnp.ndarray   # [n] i32 per-reader completed reads
    credit: jnp.ndarray       # [n] i32 scratch turns before next read
    gapv: jnp.ndarray         # [n] i32 per-reader (seed-jittered) gap
    w_target: jnp.ndarray     # [] i32 writer obligation (elastic retire)
    r_target: jnp.ndarray     # [n] i32 per-reader obligation (elastic)
    check_fails: jnp.ndarray  # [] i32
    rounds: jnp.ndarray       # [] i32


def _max_events(cfg: Config) -> int:
    return cfg.n_writes + cfg.n_agents * cfg.reads_per_reader * (cfg.gap + 4) \
        + 4 * cfg.n_agents


def _lanes(cfg: Config):
    return jnp.arange(cfg.n_agents, dtype=jnp.int32)


def _can_local(wl, s: RLState):
    cfg = wl.cfg
    lanes = _lanes(cfg)
    reader = (s.reads_done < s.r_target) & (s.credit > 0)
    return jnp.where(lanes == 0, s.writes_done < s.w_target, reader)


def _can_remote(wl, s: RLState):
    lanes = _lanes(wl.cfg)
    return (lanes > 0) & (s.reads_done < s.r_target) & (s.credit == 0)


def _remote_bound(wl, s: RLState):
    lanes = _lanes(wl.cfg)
    return jnp.where(lanes > 0,
                     s.credit.astype(jnp.float32) * wl.cfg.scratch_cost,
                     harness.BIG)


def _live(wl, s: RLState):
    cfg = wl.cfg
    lanes = _lanes(cfg)
    work = (s.writes_done < s.w_target) \
        | jnp.any((lanes > 0) & (s.reads_done < s.r_target))
    return work & (s.rounds < _max_events(cfg))


def _retire(wl, s: RLState, dead, *ops) -> RLState:
    """Elastic retirement (DESIGN.md §10): a dead writer stops owing
    versions (the payload audit compares against the bookkept
    `writes_done`, so already-published versions are still checked); a
    dead reader stops owing reads.  Bitwise identity for all-False
    `dead`."""
    dead = jnp.asarray(dead, bool)
    return s._replace(
        w_target=jnp.where(dead[0],
                           jnp.minimum(s.w_target, s.writes_done),
                           s.w_target),
        r_target=jnp.where(dead, jnp.minimum(s.r_target, s.reads_done),
                           s.r_target))


def _admit(wl, s: RLState, join, *ops) -> RLState:
    """Elastic (re-)admission: a joining writer owes one more version, a
    joining reader one more read."""
    join = jnp.asarray(join, bool)
    lanes = _lanes(wl.cfg)
    return s._replace(
        w_target=jnp.where(join[0], s.writes_done + 1, s.w_target),
        r_target=jnp.where(join & (lanes > 0), s.reads_done + 1,
                           s.r_target))


def _local_turn(wl, s: RLState, mask) -> RLState:
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    n = cfg.n_agents
    lanes = _lanes(cfg)
    is0 = lanes == 0
    wmask = mask & is0
    rmask = mask & ~is0
    zeros = jnp.zeros((n,), jnp.int32)

    st = s.store
    # writer: publish version writes_done+1 to every payload word inside
    # its own critical section (LOCAL-scope sync)
    st, _ = O.acquire(wl.proto, pc, st, wmask, zeros, 0, 1, scope=O.LOCAL)
    ver = jnp.broadcast_to(s.writes_done + 1, (n,))
    for j in range(cfg.payload_w):
        st, _ = O.store(pc, st, wmask, zeros + 2 + j, ver)
    st = O.release(wl.proto, pc, st, wmask, zeros, 0, scope=O.LOCAL)
    # readers: scratch write in their own regions
    scr = lanes * cfg.stride + 2 + s.credit % jnp.int32(8)
    st, _ = O.store(pc, st, rmask, scr, s.credit)
    st = harness.charge(st, mask, cfg.scratch_cost)

    return RLState(
        store=st,
        writes_done=s.writes_done + wmask[0].astype(jnp.int32),
        reads_done=s.reads_done,
        credit=s.credit - rmask.astype(jnp.int32),
        gapv=s.gapv,
        w_target=s.w_target, r_target=s.r_target,
        check_fails=s.check_fails,
        rounds=s.rounds + jnp.sum(mask.astype(jnp.int32)))


def _remote_turn(wl, s: RLState, wg) -> RLState:
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    do = _can_remote(wl, s)[wg]   # the scheduler's own predicate, in sync

    def read(s: RLState) -> RLState:
        st = s.store
        hot = harness.one_hot(cfg.n_agents, wg)
        st, old_v = O.acquire(wl.proto, pc, st, hot, 0, 0, 1, scope=O.REMOTE)
        old = old_v[wg]
        st, v0 = P.load(pc, st, wg, 2)
        fails = (old != 0).astype(jnp.int32) \
            + (v0 != s.writes_done).astype(jnp.int32)
        for j in range(1, cfg.payload_w):
            st, vj = P.load(pc, st, wg, 2 + j)
            fails = fails + (vj != v0).astype(jnp.int32)  # torn read
        st = O.release(wl.proto, pc, st, hot, 0, 0, scope=O.REMOTE)
        return RLState(
            store=st,
            writes_done=s.writes_done,
            reads_done=s.reads_done.at[wg].add(1),
            credit=s.credit.at[wg].set(s.gapv[wg]),
            gapv=s.gapv,
            w_target=s.w_target, r_target=s.r_target,
            check_fails=s.check_fails + fails,
            rounds=s.rounds + 1)

    def idle(s: RLState) -> RLState:
        return s._replace(rounds=s.rounds + 1)

    return lax.cond(do, read, idle, s)


def build_workload(cfg: Config, proto: P.Protocol) -> harness.Workload:
    return harness.Workload(
        name="reader_lock", cfg=cfg, proto=proto, has_remote=True,
        can_local=_can_local, can_remote=_can_remote,
        local_turn=_local_turn, remote_turn=_remote_turn,
        remote_bound=_remote_bound, live=_live,
        retire=_retire, admit=_admit)


def init_state(wl, seed) -> RLState:
    cfg = wl.cfg
    lanes = _lanes(cfg)
    seed = jnp.asarray(seed, jnp.int32)
    gapv = cfg.gap + jnp.mod(seed * 31 + lanes * 7, jnp.int32(3))
    gapv = jnp.where(lanes == 0, 0, gapv).astype(jnp.int32)
    n = cfg.n_agents
    return RLState(
        store=P.make_store(cfg.proto_cfg()),
        writes_done=jnp.int32(0),
        reads_done=jnp.zeros((n,), jnp.int32),
        credit=gapv.copy(),  # distinct buffer: the state is donated
        gapv=gapv,
        w_target=jnp.int32(cfg.n_writes),
        r_target=jnp.where(lanes == 0, 0,
                           cfg.reads_per_reader).astype(jnp.int32),
        check_fails=jnp.int32(0),
        rounds=jnp.int32(0))


def self_check(wl, final: RLState) -> dict:
    """In-run torn/stale failures + drained-L2 final-version audit."""
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    fails = int(final.check_fails)
    done = int(final.writes_done) >= int(final.w_target) and bool(
        np.all(np.asarray(final.reads_done)[1:]
               >= np.asarray(final.r_target)[1:]))
    st = harness.drain_all(pc, final.store)
    l2 = np.asarray(st.l2).reshape(-1)
    # audit against the bookkept publish count, not the static config —
    # an elastically retired writer legitimately stops short
    fails += int(np.sum(l2[2:2 + cfg.payload_w] != int(final.writes_done)))
    return {"ok": fails == 0 and done, "check_fails": fails,
            "done": done, "events": int(final.rounds)}


def build(scenario: str, n_agents: int, seed: int = 0, *,
          proto: P.Protocol = None, **kw) -> harness.Bench:
    return harness.make_bench(Config(n_agents=n_agents, **kw),
                              build_workload, init_state, self_check,
                              scenario, seed, proto)
