"""Workload-agnostic asymmetric-sharing harness (DESIGN.md §7).

The paper evaluates sRSP on exactly one driver — Cederman–Tsigas
work-stealing — but the protocol's claim is about *asymmetric sharing* in
general: many cheap local-scope operations on privately-owned data,
punctuated by rare remote-scope operations that observe another agent's
state.  This module extracts the two schedulers that used to live inside
`core/worksteal.py` into a generic pair that any workload can bind
against, so new sharing shapes (producer/consumer drains, reader-heavy
locks, directory lookups, …) plug in as declarative specs instead of
forked engines.

A workload is a `Workload` — a frozen, hashable bundle of module-level
functions plus its static config and protocol op-table:

  can_local(wl, s, *ops)     -> [n] bool  agents with a commuting turn ready
  can_remote(wl, s, *ops)    -> [n] bool  agents whose next turn conflicts
  local_turn(wl, s, mask, *ops) -> s'     execute one turn for every masked
                                          agent at once, via the masked
                                          multi-cache protocol ops
  remote_turn(wl, s, wg, *ops) -> s'      one serializing turn for agent wg
                                          (must internally no-op when
                                          can_remote[wg] is False)
  remote_bound(wl, s, *ops)  -> [n] f32   lower bound on extra cycles before
                                          agent i's *next* remote turn (BIG
                                          for agents that never go remote)
  live(wl, s, *ops)          -> bool      while-loop guard (work remains and
                                          the event budget isn't exhausted)

The state `s` is an arbitrary NamedTuple whose first field is the protocol
`Store` (the harness reads per-agent clocks from
`s.store.counters.cycles`); everything else — queue occupancy, quotas,
bookkeeping ground truth for the workload's self-check — is workload
private.

Scheduling contract (identical to the work-steal engines it was extracted
from; proofs in DESIGN.md §4/§7):

* `run_serial` is the reference: one turn per `lax.while_loop` trip, the
  candidate with the smallest cycle clock acts next, ties to the lowest
  index.  A candidate with a local turn runs `local_turn` with a one-hot
  mask; otherwise `remote_turn`.
* `run_batched` executes every local turn that *provably precedes* —
  in the serial order — every remote turn that could observe it: batch
  agent i iff `can_local[i]` and its clock beats every currently
  remote-capable clock (argmin-index tie-break) and every future
  first-remote lower bound `clock[j] + remote_bound[j]`.  Local turns of
  distinct agents must commute (pairwise-disjoint L2 words — that is the
  workload's declarative obligation), so the batched schedule is a
  reordering of the serial one within commuting spans and final states
  are bitwise identical.
* when a workload declares the remote-batching capability
  (`remote_turn_b` + `remote_addr`) AND its protocol declares batched
  address-disjoint remote twins (`Protocol.remote_batchable`),
  `run_batched` additionally co-schedules non-conflicting remote turns:
  all remote-capable agents that precede every local candidate
  (clock-lex) and target pairwise-distinct addresses run in ONE masked
  remote turn (DESIGN.md §9 has the commutation rule and its hazard
  argument).  Protocols without the capability — original RSP, whose
  remote op flushes every cache — serialize exactly as before.

Buffer donation (ROADMAP open item: n_wgs=256 is memory-bound): the
harness entry points donate the state argument, so XLA may alias the
~O(n_caches · n_words) Store buffers through the jit boundary instead of
copying them per call.  Set REPRO_NO_DONATE=1 before import to disable
(used by the sweep's before/after measurement).  Callers must not reuse a
state object after passing it in.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import protocol as P
from repro.kernels import fused_turn
from repro.obs import trace as T

BIG = jnp.float32(3e38)

# scenario -> protocol name, subsystem-wide (the paper's §5 mapping;
# worksteal additionally flags which scenarios steal).  A registry: an
# unknown scenario or protocol name raises with the registered list.
SCENARIO_PROTOCOLS = P.Registry("scenario")


def register_scenario(name: str, proto_name: str) -> None:
    """Map a scenario name onto a registered protocol name."""
    if proto_name not in P.PROTOCOLS:
        raise KeyError(f"cannot register scenario {name!r}: unknown "
                       f"protocol {proto_name!r}; registered protocols: "
                       f"{sorted(P.PROTOCOLS)}")
    SCENARIO_PROTOCOLS[name] = proto_name


def scenarios() -> tuple:
    """Names of every registered scenario, sorted."""
    return tuple(sorted(SCENARIO_PROTOCOLS))


register_scenario("baseline", "global")
register_scenario("scope_only", "local")  # NOT remote-safe — staleness demo
register_scenario("steal_only", "global")
register_scenario("rsp", "rsp")
register_scenario("srsp", "srsp")


def resolve_proto(scenario: str, proto: P.Protocol = None) -> P.Protocol:
    """Scenario's protocol table, overridable for fault injection.
    Unknown scenario names raise with the registered list."""
    if proto is not None:
        return proto
    return P.get_protocol(SCENARIO_PROTOCOLS[scenario])


class Bench(NamedTuple):
    """Uniform handle the sweep/tests drive a workload through."""
    wl: "Workload"
    state: Any              # initial state (fresh per engine run — donation!)
    ops: tuple              # extra operand arrays for the scheduler fns
    check: Callable         # (final_state) -> dict (ok, check_fails, ...)


def make_bench(cfg, build_workload, init_state, self_check, scenario,
               seed=0, proto: P.Protocol = None) -> Bench:
    """The standard build() body shared by the jnp-pure workloads."""
    wl = build_workload(cfg, resolve_proto(scenario, proto))
    return Bench(wl, init_state(wl, seed), (),
                 lambda final: self_check(wl, final))

# Donation toggle is read once at import: the jitted entry points below are
# module-level, so the flag must be process-wide (the sweep A/B-tests it in
# subprocesses).
DONATE = os.environ.get("REPRO_NO_DONATE", "0") != "1"
_don = {"donate_argnums": (1,)} if DONATE else {}

# Fused-trip escape hatch (DESIGN.md §12), read once at import like the
# donation/packing flags: REPRO_NO_FUSE=1 makes `engine="fused"` execute
# the plain `_batched_trip` path (the jnp reference the fused plan is
# pinned against), so a kernel suspect can be excluded in one env var
# without touching any engine-name plumbing.
FUSE = os.environ.get("REPRO_NO_FUSE", "0") != "1"


@dataclasses.dataclass(frozen=True)
class Workload:
    """Declarative workload spec bound to a config and a protocol.

    Instances are jit static arguments: keep `cfg` a frozen dataclass and
    every function a module-level def so two equal-valued Workloads hash
    equal and share compiled schedulers.

    `remote_turn_b`/`remote_addr` are the optional remote-batching
    capability (DESIGN.md §9): `remote_turn_b(wl, s, mask, *ops)`
    executes one remote turn for every masked agent at once (through the
    protocol's batched remote twins), and `remote_addr(wl, s, *ops)`
    names the L2 sync address agent i's next remote turn will target.
    Declaring them asserts the workload's remote-commutation obligations
    (§9): remote turns of distinct agents on distinct addresses must be
    pairwise commuting, with target choice and capability derived from
    per-agent-private bookkeeping.  The harness only co-schedules when
    the bound protocol also declares `remote_batchable`."""
    name: str
    cfg: Any                    # frozen workload config (hashable)
    proto: P.Protocol           # registered scope-parametric op table
    has_remote: bool            # False => every turn commutes (static)
    can_local: Callable
    can_remote: Callable
    local_turn: Callable
    remote_turn: Callable
    remote_bound: Callable
    live: Callable
    remote_turn_b: Callable = None   # masked multi-agent remote turn
    remote_addr: Callable = None     # [n] i32 next-remote target address
    # elastic alive-set hooks (DESIGN.md §10).  `retire(wl, s, dead, *ops)
    # -> s'` forgives a dying agent's remaining obligations in the
    # bookkeeping ground truth (quotas := done) so the run terminates and
    # the self-check scores survivors only; it must be a bitwise identity
    # when `dead` is all-False.  `admit(wl, s, joined, *ops) -> s'`
    # optionally assigns new work to re-admitted agents.
    retire: Callable = None          # masked retirement bookkeeping
    admit: Callable = None           # masked (re-)admission bookkeeping


def one_hot(n: int, wg) -> jnp.ndarray:
    return jnp.arange(n, dtype=jnp.int32) == jnp.asarray(wg, jnp.int32)


def charge(st: P.Store, mask, cycles) -> P.Store:
    """Add per-agent compute cycles outside the protocol ops (task work)."""
    c = st.counters
    return st._replace(counters=c._replace(
        cycles=c.cycles + jnp.where(mask, jnp.float32(cycles), 0.0)))


def _note_turn(s0, s1):
    """Bucket each agent's charged cycles across one scheduler turn/trip
    into the trace's per-turn latency histogram (DESIGN.md §11).  A
    Python-level identity when tracing is off, so the plain engines'
    bitwise contracts are untouched by default."""
    if not T.enabled(s1.store.trace):
        return s1
    return s1._replace(store=T.record_turn(s1.store,
                                           s0.store.counters.cycles))


def _serial_turn(wl: Workload, s, wg, can_l, ops):
    n = s.store.counters.cycles.shape[0]
    hot = one_hot(n, wg)
    return lax.cond(
        can_l[wg],
        lambda st: wl.local_turn(wl, st, hot, *ops),
        lambda st: wl.remote_turn(wl, st, wg, *ops),
        s)


@partial(jax.jit, static_argnums=(0,), **_don)
def run_serial(wl: Workload, state, *ops):
    """Event-driven reference scheduler: smallest clock acts next."""

    def cond(s):
        return wl.live(wl, s, *ops)

    def body(s):
        can_l = wl.can_local(wl, s, *ops)
        can_r = wl.can_remote(wl, s, *ops)
        cand = can_l | can_r
        clocks = jnp.where(cand, s.store.counters.cycles, BIG)
        wg = jnp.argmin(clocks).astype(jnp.int32)
        return _note_turn(s, _serial_turn(wl, s, wg, can_l, ops))

    return lax.while_loop(cond, body, state)


def _batched_trip(wl: Workload, s, can_l, can_r, horizon, ops):
    """One `run_batched` trip, given the trip's readiness masks.

    `horizon` is the elastic engines' event fence: a turn at clock >=
    horizon must not execute this trip (a churn event or lease expiry
    fires first — DESIGN.md §10).  The plain engines pass None and the
    masking disappears at trace time, keeping their schedule untouched."""
    n = s.store.counters.cycles.shape[0]
    wgs = jnp.arange(n, dtype=jnp.int32)
    remote_cap = (wl.remote_turn_b is not None
                  and wl.remote_addr is not None
                  and wl.proto.remote_batchable)
    clocks_all = s.store.counters.cycles
    if not wl.has_remote:
        # nothing ever conflicts: every ready agent acts each trip
        if horizon is not None:
            can_l = can_l & (clocks_all < horizon)
        return wl.local_turn(wl, s, can_l, *ops)
    cand = can_l | can_r
    clocks = jnp.where(cand, clocks_all, BIG)
    wg_min = jnp.argmin(clocks).astype(jnp.int32)
    sclk = jnp.where(can_r, clocks_all, BIG)
    ms = jnp.min(sclk)
    js = jnp.argmin(sclk).astype(jnp.int32)
    fence = jnp.min(jnp.where(can_l,
                              clocks_all + wl.remote_bound(wl, s, *ops),
                              BIG))
    lex = (clocks_all < ms) | ((clocks_all == ms) & (wgs < js))
    batch = can_l & lex & (clocks_all <= fence)
    if horizon is not None:
        batch = batch & (clocks_all < horizon)

    def do_batch(st):
        return wl.local_turn(wl, st, batch, *ops)

    def do_serial(st):
        return _serial_turn(wl, st, wg_min, can_l, ops)

    if remote_cap:
        def do_remote_or_serial(st):
            # remote candidates preceding every local candidate's
            # clock (same lex pattern as the local batch, mirrored)
            lclk = jnp.where(can_l, clocks_all, BIG)
            ml = jnp.min(lclk)
            jl = jnp.argmin(lclk).astype(jnp.int32)
            lexr = (clocks_all < ml) | ((clocks_all == ml) & (wgs < jl))
            r0 = can_r & lexr
            if horizon is not None:
                r0 = r0 & (clocks_all < horizon)
            raddr = wl.remote_addr(wl, st, *ops)
            # address dedup: drop a lane iff an earlier (clock, idx)
            # candidate targets the same address
            collide = r0[:, None] & r0[None, :] \
                & (raddr[:, None] == raddr[None, :])
            earlier = (clocks_all[None, :] < clocks_all[:, None]) \
                | ((clocks_all[None, :] == clocks_all[:, None])
                   & (wgs[None, :] < wgs[:, None]))
            rbatch = r0 & ~jnp.any(collide & earlier, axis=1)
            return lax.cond(
                jnp.any(rbatch),
                lambda s2: wl.remote_turn_b(wl, s2, rbatch, *ops),
                do_serial, st)

        fallback = do_remote_or_serial
    else:
        fallback = do_serial

    return lax.cond(jnp.any(batch), do_batch, fallback, s)


@partial(jax.jit, static_argnums=(0,), **_don)
def run_batched(wl: Workload, state, *ops):
    """Vectorized scheduler: every provably-commuting local turn per trip.

    Batch rule (DESIGN.md §4): agent i's local turn joins the batch iff
    its clock precedes (a) every currently remote-capable agent's clock,
    with the serial argmin-index tie-break, and (b) every future
    first-remote lower bound clock[j] + remote_bound[j].

    Remote co-scheduling (DESIGN.md §9): when the local batch is empty
    and both the workload (`remote_turn_b`/`remote_addr`) and the
    protocol (`remote_batchable`) declare the capability, every
    remote-capable agent whose clock precedes every local candidate's
    clock (argmin-index tie-break) joins a remote batch — minus any lane
    whose target address collides with an earlier-clock batch member
    (the earlier lane keeps it; the later retries next trip).  Otherwise
    the trip falls back to one serial turn — remote turns execute alone,
    exactly at their serial position."""

    def cond(s):
        return wl.live(wl, s, *ops)

    def body(s):
        can_l = wl.can_local(wl, s, *ops)
        can_r = wl.can_remote(wl, s, *ops) if wl.has_remote else None
        return _note_turn(s, _batched_trip(wl, s, can_l, can_r, None, ops))

    return lax.while_loop(cond, body, state)


@partial(jax.jit, static_argnums=(0,), **_don)
def run_batched_many(wl: Workload, states, *ops):
    """vmap of `run_batched` over a leading replica axis of `states`.

    One compilation covers every replica of a (workload, protocol, size)
    cell — the sweep's few-compilations path.  Finished replicas no-op
    (every turn is internally guarded) while stragglers drain."""
    return jax.vmap(lambda s: run_batched.__wrapped__(wl, s, *ops))(states)


def _fused_trip(wl: Workload, s, can_l, can_r, horizon, ops):
    """`_batched_trip` with the scheduling decision fused into one
    kernel-shaped plan and the turn execution restructured (DESIGN.md
    §12, bitwise-equivalence argument there):

      * the whole select-commuting-pops decision — batch lex/fence
        masks, remote co-schedule address dedup, serial-fallback agent —
        is ONE `fused_turn.trip_plan` call (the Pallas megakernel on
        TPU; its jnp reference, extracted verbatim from `_batched_trip`,
        on CPU);
      * the serial LOCAL fallback is folded into the SAME masked
        `local_turn` as the batch (`plan.lmask` one-hots the argmin
        agent when the batch is empty and it has a local turn) — §12
        proves the remote batch is necessarily empty in that case, so
        the trip runs `local_turn` ONCE instead of twice.  Under vmap
        (`run_fused_many`, the sweep path) `lax.cond` lowers to
        executing both branches, so this halves the local-turn work per
        trip per replica — the fused engine's steady-state win.

    Costmodel charging and trace events stay OUTSIDE the kernel
    boundary: only readiness masks, clocks, bounds and addresses cross
    into the plan, and the turns charge/record exactly as in
    `_batched_trip` — the trace-stripped equivalence suites hold."""
    if not wl.has_remote:
        return _batched_trip(wl, s, can_l, can_r, horizon, ops)
    remote_cap = (wl.remote_turn_b is not None
                  and wl.remote_addr is not None
                  and wl.proto.remote_batchable)
    raddr = wl.remote_addr(wl, s, *ops) if remote_cap else None
    plan = fused_turn.trip_plan(
        s.store.counters.cycles, can_l, can_r,
        wl.remote_bound(wl, s, *ops), raddr, horizon,
        remote_cap=remote_cap)

    def do_local(st):
        return wl.local_turn(wl, st, plan.lmask, *ops)

    if remote_cap:
        def do_remote(st):
            return lax.cond(
                jnp.any(plan.rmask),
                lambda s2: wl.remote_turn_b(wl, s2, plan.rmask, *ops),
                lambda s2: wl.remote_turn(wl, s2, plan.wg, *ops), st)
    else:
        def do_remote(st):
            return wl.remote_turn(wl, st, plan.wg, *ops)

    return lax.cond(jnp.any(plan.lmask), do_local, do_remote, s)


@partial(jax.jit, static_argnums=(0,), **_don)
def run_fused(wl: Workload, state, *ops):
    """`run_batched` with the fused trip (DESIGN.md §12): bitwise the
    same schedule and final state, one fused plan + at most one masked
    local turn per trip.  REPRO_NO_FUSE=1 (read at import) swaps the
    body back to `_batched_trip` — the engine name keeps resolving, the
    fused math never runs."""
    trip = _fused_trip if FUSE else _batched_trip

    def cond(s):
        return wl.live(wl, s, *ops)

    def body(s):
        can_l = wl.can_local(wl, s, *ops)
        can_r = wl.can_remote(wl, s, *ops) if wl.has_remote else None
        return _note_turn(s, trip(wl, s, can_l, can_r, None, ops))

    return lax.while_loop(cond, body, state)


@partial(jax.jit, static_argnums=(0,), **_don)
def run_fused_many(wl: Workload, states, *ops):
    """vmap of `run_fused` over a leading replica axis (the sweep's
    few-compilations path, mirroring `run_batched_many`)."""
    return jax.vmap(lambda s: run_fused.__wrapped__(wl, s, *ops))(states)


# --------------------------------------------------------------------------
# Elastic alive-set scheduling (DESIGN.md §10).
#
# The plain engines assume a static agent set; production sharing tiers see
# churn.  The elastic engines wrap any workload state in an `ElasticState`
# carrying an alive mask, and replay a seeded `ChurnSchedule` of
# join/leave/crash events against it mid-run:
#
#   * a churn event at clock T serializes against every turn at clock >= T
#     — in BOTH engines, so serial/batched stay bitwise identical under
#     churn.  The batched trip simply fences its batch at the event
#     horizon (`_batched_trip(horizon=...)`).
#   * LEAVE retires the agent (the workload's `retire` hook forgives its
#     remaining obligations) and reclaims its caches immediately.
#   * CRASH retires the agent but the directory may only reclaim once the
#     agent's clock-stamped lease (ops.acquire/release stamp it) expires:
#     recovery fires at T + lease via `Protocol.recover_b` — drain the dead
#     agent's dirty words through the existing writeback machinery,
#     force-release its leased sync word at L2, invalidate its PA/LR
#     entries.  A protocol with `recover_b=None` (faults.lease_never_expires)
#     never reclaims: survivors observe whatever the crash stranded.
#   * JOIN re-admits the agent (the workload's `admit` hook may assign it
#     new work).  Schedule JOINs for crashed agents only after their lease
#     expired — re-admitting an unreclaimed cache is the operator's hazard.
#
# Zero churn is bitwise-exact: an empty schedule keeps every event horizon
# at BIG, the fences reduce to `clock < BIG` (always true for f32 cycle
# clocks), the alive mask stays all-True (`can & True == can`), and the
# fire branch of the lax.cond never executes.
# --------------------------------------------------------------------------

LEAVE, CRASH, JOIN = 0, 1, 2
KIND_CODES = {"leave": LEAVE, "crash": CRASH, "join": JOIN}


class ChurnSchedule(NamedTuple):
    """Seeded churn event stream, carried as a scheduler op (traced)."""
    clock: jnp.ndarray   # [k] f32 fire time (BIG = padding, never fires)
    agent: jnp.ndarray   # [k] i32 subject agent
    kind: jnp.ndarray    # [k] i32 LEAVE / CRASH / JOIN
    lease: jnp.ndarray   # [] f32 promotion/lock-hold lease (cycles)


class ElasticState(NamedTuple):
    """Workload state + alive-set bookkeeping threaded through the run."""
    s: Any                   # workload state (first field is the Store)
    alive: jnp.ndarray       # [n] bool scheduling-eligible agents
    recover_at: jnp.ndarray  # [n] f32 pending reclaim clock (BIG = none)
    fired: jnp.ndarray       # [k] bool churn events already replayed


def make_churn(events=(), lease=0.0) -> ChurnSchedule:
    """Build a schedule from (clock, agent, kind) triples; kind is a
    KIND_CODES string or int code.  Always at least one (inert) entry so
    the event-horizon reductions never see a zero-length axis."""
    k = max(len(events), 1)
    clock = [float(BIG)] * k
    agent = [0] * k
    kind = [LEAVE] * k
    for j, (t, a, kd) in enumerate(events):
        clock[j] = float(t)
        agent[j] = int(a)
        kind[j] = KIND_CODES[kd] if isinstance(kd, str) else int(kd)
    return ChurnSchedule(clock=jnp.asarray(clock, jnp.float32),
                         agent=jnp.asarray(agent, jnp.int32),
                         kind=jnp.asarray(kind, jnp.int32),
                         lease=jnp.asarray(float(lease), jnp.float32))


def make_elastic(bench: Bench, events=(), lease=0.0) -> Bench:
    """Wrap a Bench for the elastic engines: ElasticState state, the
    churn schedule prepended to ops, check unwrapped to the inner state."""
    sched = make_churn(events, lease)
    n = bench.state.store.counters.cycles.shape[0]
    es = ElasticState(s=bench.state,
                      alive=jnp.ones((n,), bool),
                      recover_at=jnp.full((n,), BIG),
                      fired=sched.clock >= BIG)
    return Bench(bench.wl, es, (sched,) + bench.ops,
                 lambda final: bench.check(final.s))


def _elastic_ready(wl: Workload, es: ElasticState, ops):
    """Alive-masked readiness: dead agents never act (can_r all-False for
    workloads without remote turns)."""
    can_l = wl.can_local(wl, es.s, *ops) & es.alive
    if wl.has_remote:
        can_r = wl.can_remote(wl, es.s, *ops) & es.alive
    else:
        can_r = jnp.zeros_like(es.alive)
    return can_l, can_r


def _event_horizon(sched: ChurnSchedule, es: ElasticState) -> jnp.ndarray:
    """Earliest unfired churn event or pending lease reclaim (BIG: none)."""
    ec = jnp.min(jnp.where(es.fired, BIG, sched.clock))
    return jnp.minimum(ec, jnp.min(es.recover_at))


def _fire_events(wl: Workload, sched: ChurnSchedule, es: ElasticState,
                 mcc, ops) -> ElasticState:
    """Replay every churn event and lease reclaim due at clock <= `mcc`
    (the next turn's clock).  Events replay in schedule order — the same
    deterministic position in both engines."""
    s, alive, recover_at, fired = es
    n = alive.shape[0]
    due = ~fired & (sched.clock <= mcc)

    def step(carry, j):
        s, alive, recover_at = carry
        hot = one_hot(n, sched.agent[j]) & due[j]
        kind = sched.kind[j]
        dead = hot & (kind != JOIN)
        join = hot & (kind == JOIN)
        if wl.retire is not None:
            s = wl.retire(wl, s, dead, *ops)
        if wl.admit is not None:
            s = wl.admit(wl, s, join, *ops)
        if T.enabled(s.store.trace):
            # churn event per affected lane, stamped with the schedule
            # clock; the harness LEAVE/CRASH/JOIN code rides the outcome
            s = s._replace(store=T.record_event(
                s.store, hot, T.CHURN, kind, clock=sched.clock[j]))
        alive = (alive & ~dead) | join
        # a clean LEAVE may be reclaimed at once; a CRASH's promotion
        # lease must first expire before the directory touches its state
        due_at = jnp.where(kind == CRASH, sched.clock[j] + sched.lease,
                           sched.clock[j])
        recover_at = jnp.where(dead, due_at, recover_at)
        return (s, alive, recover_at), None

    (s, alive, recover_at), _ = lax.scan(
        step, (s, alive, recover_at),
        jnp.arange(sched.clock.shape[0]))
    fired = fired | due
    reclaim = (recover_at <= mcc) & (recover_at < BIG)
    if wl.proto.recover_b is not None:
        s = lax.cond(
            jnp.any(reclaim),
            lambda st: st._replace(store=wl.proto.recover_b(
                wl.cfg.proto_cfg(), st.store, reclaim)),
            lambda st: st, s)
    # cleared even when recover_b is None: the reclaim point passed and
    # nothing happened — that IS the lease_never_expires semantics, and
    # leaving it pending would spin the scheduler forever
    recover_at = jnp.where(reclaim, BIG, recover_at)
    return ElasticState(s, alive, recover_at, fired)


def _elastic_cond(wl: Workload, sched: ChurnSchedule, es: ElasticState,
                  ops):
    """Loop guard: work remains AND progress is possible — a live agent
    can act, or an event/reclaim is still due to fire.  Unlike the plain
    engines this cannot rely on `live` alone: a crashed agent's
    unforgivable leftovers (e.g. a dead queue nobody may steal from)
    would otherwise wedge the loop; here the run terminates and the
    self-check reports the loss instead."""
    can_l, can_r = _elastic_ready(wl, es, ops)
    pending = _event_horizon(sched, es) < BIG
    return wl.live(wl, es.s, *ops) & (jnp.any(can_l | can_r) | pending)


@partial(jax.jit, static_argnums=(0,), **_don)
def run_serial_elastic(wl: Workload, es: ElasticState,
                       sched: ChurnSchedule, *ops):
    """`run_serial` over a churn-varying alive-set.  With an empty
    schedule the trip sequence is bitwise identical to `run_serial`."""

    def cond(e):
        return _elastic_cond(wl, sched, e, ops)

    def body(e):
        can_l, can_r = _elastic_ready(wl, e, ops)
        clocks = jnp.where(can_l | can_r, e.s.store.counters.cycles, BIG)
        mcc = jnp.min(clocks)
        wg = jnp.argmin(clocks).astype(jnp.int32)
        ec = _event_horizon(sched, e)
        return lax.cond(
            (ec <= mcc) & (ec < BIG),
            lambda e2: _fire_events(wl, sched, e2, mcc, ops),
            lambda e2: e2._replace(
                s=_note_turn(e2.s, _serial_turn(wl, e2.s, wg, can_l,
                                                ops))),
            e)

    return lax.while_loop(cond, body, es)


@partial(jax.jit, static_argnums=(0,), **_don)
def run_batched_elastic(wl: Workload, es: ElasticState,
                        sched: ChurnSchedule, *ops):
    """`run_batched` over a churn-varying alive-set: the trip is fenced
    at the event horizon so no turn at clock >= the next event executes
    before the event fires — the reordering argument of DESIGN.md §4/§9
    then applies span-by-span between events.  With an empty schedule the
    trip sequence is bitwise identical to `run_batched`."""

    def cond(e):
        return _elastic_cond(wl, sched, e, ops)

    def body(e):
        can_l, can_r = _elastic_ready(wl, e, ops)
        clocks = jnp.where(can_l | can_r, e.s.store.counters.cycles, BIG)
        mcc = jnp.min(clocks)
        ec = _event_horizon(sched, e)
        cr = can_r if wl.has_remote else None
        return lax.cond(
            (ec <= mcc) & (ec < BIG),
            lambda e2: _fire_events(wl, sched, e2, mcc, ops),
            lambda e2: e2._replace(
                s=_note_turn(e2.s, _batched_trip(wl, e2.s, can_l, cr, ec,
                                                 ops))),
            e)

    return lax.while_loop(cond, body, es)


# Engine registry: unknown names raise with the registered list.
ENGINES = P.Registry("engine")


def register_engine(name: str, fn: Callable) -> Callable:
    ENGINES[name] = fn
    return fn


def engines() -> tuple:
    """Names of every registered engine, sorted."""
    return tuple(sorted(ENGINES))


register_engine("serial", run_serial)
register_engine("batched", run_batched)
register_engine("fused", run_fused)
register_engine("serial_elastic", run_serial_elastic)
register_engine("batched_elastic", run_batched_elastic)

# Vmapped (replicated) twins for the engines the sweep packs replicas
# through — one compiled `run_*_many` per (workload, protocol, size) cell.
ENGINES_MANY = P.Registry("vmapped engine")
ENGINES_MANY["batched"] = run_batched_many
ENGINES_MANY["fused"] = run_fused_many


def runner(engine: str):
    """Registered scheduler by name; unknown names raise with the list."""
    return ENGINES[engine]


def runner_many(engine: str):
    """Vmapped scheduler twin by engine name (sweep replica packing)."""
    return ENGINES_MANY[engine]


def drain_all(cfg: P.ProtoConfig, st: P.Store) -> P.Store:
    """Flush every cache completely (post-run memory audits)."""
    n = cfg.n_caches
    st, _ = P.b_drain(cfg, st, jnp.full((n,), P.DRAIN_ALL),
                      jnp.ones((n,), bool))
    return st


def counters_dict(st: P.Store) -> dict:
    """The standard counter summary every workload reports (run_app's set)."""
    from repro.core import costmodel
    c = st.counters
    return {
        "makespan": float(costmodel.makespan(c)),
        "l2_accesses": float(c.l2_accesses),
        "wb_blocks": float(c.wb_blocks),
        "inv_full": float(c.inv_full),
        "probes": float(c.probes),
        "promotions": float(c.promotions),
        "local_syncs": float(c.local_syncs),
        "remote_syncs": float(c.remote_syncs),
        "global_syncs": float(c.global_syncs),
        "steals": float(c.steals),
        "l1_hits": float(c.l1_hits),
        "l1_misses": float(c.l1_misses),
        "recoveries": float(c.recoveries),
    }
