"""Multi-consumer producer/consumer — the ROADMAP follow-up variant.

Identical spec to `producer_consumer`, with the consumer side scaled to
``max(2, n_agents // 8)`` drainers (one per 8 agents, minimum two) so
the rare remote work itself parallelizes: partitioned victims give every
concurrent drain a distinct lock address, the workload declares the
remote-batching capability (DESIGN.md §9), and protocols with batched
remote twins (srsp, global, local) co-schedule the drains in one masked
turn.  This is the configuration under which producer_consumer's
"single always-hot drainer IS the makespan" structural bound (ROADMAP,
BENCH_workloads.json metric_note) can finally break — the sweep records
whether srsp reaches baseline parity here either way.
"""
from __future__ import annotations

from repro.core import protocol as P
from repro.workloads import harness, producer_consumer as _pc

VMAPPABLE = True

Config = _pc.Config
PCState = _pc.PCState
init_state = _pc.init_state
self_check = _pc.self_check
build_workload = _pc.build_workload


def default_consumers(n_agents: int) -> int:
    """One drainer per 8 agents, minimum two — clamped so tiny machines
    (n_agents <= 2) degrade to the single-consumer shape instead of
    tripping build_workload's n_consumers < n_agents guard."""
    return max(1, min(n_agents - 1, max(2, n_agents // 8)))


def build(scenario: str, n_agents: int, seed: int = 0, *,
          proto: P.Protocol = None, **kw) -> harness.Bench:
    kw.setdefault("n_consumers", default_consumers(n_agents))
    return _pc.build(scenario, n_agents, seed, proto=proto, **kw)
