"""Protocol fault injection as *protocol derivation*.

Every workload's declarative spec carries a consistency check (lost
updates, stale reads) that reads values THROUGH the simulated memory and
compares them against host-invisible bookkeeping ground truth.  These
helpers derive deliberately-weakened `Protocol` objects — renamed copies
with op-table entries overridden (`derive`) — from any registered
protocol; a workload whose self-check stays green under them isn't
checking anything.  Derived protocols stay unregistered: they are test
fixtures, not selectable scenarios.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import protocol as P


def derive(proto: P.Protocol, suffix: str, **overrides) -> P.Protocol:
    """A renamed copy of `proto` with op-table fields overridden — the
    one-stop protocol-derivation hook (fault injection, capability
    stripping).  Overrides name the scope-parametric fields
    (`acquire_rem`, `release_loc_b`, `acquire_rem_b`, …)."""
    return dataclasses.replace(proto, name=f"{proto.name}+{suffix}",
                               **overrides)


def _skip_promotion_acquire(cfg, st, cid, addr, expect, new):
    """Remote acquire with the promotion machinery ripped out: CAS at L2,
    but NO probe/selective-flush of remote sharers and NO own-cache
    invalidation (paper §4.2 steps 1–3 skipped).  Local sharers' released
    writes stay stranded in their L1s and the acquirer keeps serving stale
    words from its own L1 — the exact failure mode sRSP's promotion
    exists to prevent."""
    st, old = P._atomic_l2(cfg, st, cid, addr, expect, new, True)
    c = st.counters
    return st._replace(
        counters=c._replace(remote_syncs=c.remote_syncs + 1.0)), old


def no_promotion(proto: P.Protocol) -> P.Protocol:
    """`proto` with remote acquires no longer promoting (the canonical
    injected bug).  Releases keep their real semantics.  The batched
    remote twins are stripped too — the capability would otherwise route
    scoped REMOTE dispatch around the injected scalar bug.

    (A release-side fault — skipping the own-cache flush — is NOT a
    useful injection here: the next remote acquire's probe drains the
    faulty releaser's stranded writes anyway, so the protocol
    self-heals and no workload can observe it.)"""
    return derive(proto, "no_promotion",
                  acquire_rem=_skip_promotion_acquire,
                  acquire_rem_b=None, release_rem_b=None)


def serialize_remote(proto: P.Protocol) -> P.Protocol:
    """`proto` with the batched remote twins stripped: scoped REMOTE
    dispatch falls back to the scalar serializing ops and the harness
    never co-schedules remote turns.  Semantically identical on
    address-disjoint schedules (DESIGN.md §9) — the equivalence tests
    and the sweep's remote-batch A/B pin exactly that."""
    return derive(proto, "serial_remote",
                  acquire_rem_b=None, release_rem_b=None)


def crash_holding_lock(proto: P.Protocol, victim: int,
                       at: float) -> P.Protocol:
    """`proto` with agent `victim` dying *inside* a critical section at
    clock `at`: from then on its release instructions (ops.py
    `crash_gate`) never execute — acquires stay live, so the victim's
    next critical section is entered but never exited.  The lock stays
    held (its lease survives for recovery to force-release), no LR entry
    is ever inserted, and the section's data writes stay stranded dirty
    in its L1.  Pair with an elastic CRASH event a little *after* `at`
    (enough slack for one victim turn) so the lock is provably taken
    before the churn event retires the agent."""
    return derive(proto, f"crash_lock@{victim}",
                  crash_gate=(int(victim), float(at)))


def crash_dirty(proto: P.Protocol, victim: int, at: float) -> P.Protocol:
    """`proto` with agent `victim` dying at clock `at` *between* the data
    publish and its visibility plumbing: local-scope releases after `at`
    still write the released value into the victim's L1 (so its own
    bookkeeping stays consistent) but skip the real release path — no
    LR-TBL insert, so the next remote acquirer's selective-flush probe
    cannot find the dirty words and survivors read stale values from L2.
    Only the recovery drain's unconditional `b_invalidate` (which drains
    ALL dirty words, LR-covered or not) reclaims them."""
    inner = proto.release_loc_b
    victim, at = int(victim), float(at)

    def rel(cfg, st, active, addrs, vals):
        active = jnp.asarray(active, bool)
        lanes = jnp.arange(cfg.n_caches, dtype=jnp.int32)
        dying = active & (lanes == victim) \
            & (st.counters.cycles >= jnp.float32(at))
        st = inner(cfg, st, active & ~dying, addrs, vals)
        st, _ = P.b_store_word(cfg, st, dying, addrs, vals)
        return st

    return derive(proto, f"crash_dirty@{victim}", release_loc_b=rel)


def lease_never_expires(proto: P.Protocol) -> P.Protocol:
    """`proto` with the recovery capability stripped: a dead sharer's
    promotion lease never expires, so the directory never reclaims its
    lock/dirty words — the pre-lease wedge the elastic engines exist to
    prevent.  The run still terminates (the elastic loop guard exits
    when no live agent can act) but the self-check reports the loss."""
    return derive(proto, "lease_never_expires", recover_b=None)


# On the set-associative PA-TBL's silent LRU eviction (DESIGN.md §8):
# dropping only the *release-side* PA broadcast is NOT an observable fault
# for the registered workloads — the probe already re-inserts the address
# into every actual sharer's PA at acquire time, and non-sharers never
# later local-acquire these locks (verified while building this module:
# the workload checks stay green under that injection).  The observable
# limiting case of a lossy PA table is promotion starvation at the
# acquire, which `no_promotion` injects and every workload's check
# catches (tests/test_workloads.py).
