"""Protocol fault injection as *protocol derivation*.

Every workload's declarative spec carries a consistency check (lost
updates, stale reads) that reads values THROUGH the simulated memory and
compares them against host-invisible bookkeeping ground truth.  These
helpers derive deliberately-weakened `Protocol` objects — renamed copies
with op-table entries overridden (`derive`) — from any registered
protocol; a workload whose self-check stays green under them isn't
checking anything.  Derived protocols stay unregistered: they are test
fixtures, not selectable scenarios.
"""
from __future__ import annotations

import dataclasses

from repro.core import protocol as P


def derive(proto: P.Protocol, suffix: str, **overrides) -> P.Protocol:
    """A renamed copy of `proto` with op-table fields overridden — the
    one-stop protocol-derivation hook (fault injection, capability
    stripping).  Overrides name the scope-parametric fields
    (`acquire_rem`, `release_loc_b`, `acquire_rem_b`, …)."""
    return dataclasses.replace(proto, name=f"{proto.name}+{suffix}",
                               **overrides)


def _skip_promotion_acquire(cfg, st, cid, addr, expect, new):
    """Remote acquire with the promotion machinery ripped out: CAS at L2,
    but NO probe/selective-flush of remote sharers and NO own-cache
    invalidation (paper §4.2 steps 1–3 skipped).  Local sharers' released
    writes stay stranded in their L1s and the acquirer keeps serving stale
    words from its own L1 — the exact failure mode sRSP's promotion
    exists to prevent."""
    st, old = P._atomic_l2(cfg, st, cid, addr, expect, new, True)
    c = st.counters
    return st._replace(
        counters=c._replace(remote_syncs=c.remote_syncs + 1.0)), old


def no_promotion(proto: P.Protocol) -> P.Protocol:
    """`proto` with remote acquires no longer promoting (the canonical
    injected bug).  Releases keep their real semantics.  The batched
    remote twins are stripped too — the capability would otherwise route
    scoped REMOTE dispatch around the injected scalar bug.

    (A release-side fault — skipping the own-cache flush — is NOT a
    useful injection here: the next remote acquire's probe drains the
    faulty releaser's stranded writes anyway, so the protocol
    self-heals and no workload can observe it.)"""
    return derive(proto, "no_promotion",
                  acquire_rem=_skip_promotion_acquire,
                  acquire_rem_b=None, release_rem_b=None)


def serialize_remote(proto: P.Protocol) -> P.Protocol:
    """`proto` with the batched remote twins stripped: scoped REMOTE
    dispatch falls back to the scalar serializing ops and the harness
    never co-schedules remote turns.  Semantically identical on
    address-disjoint schedules (DESIGN.md §9) — the equivalence tests
    and the sweep's remote-batch A/B pin exactly that."""
    return derive(proto, "serial_remote",
                  acquire_rem_b=None, release_rem_b=None)


# On the set-associative PA-TBL's silent LRU eviction (DESIGN.md §8):
# dropping only the *release-side* PA broadcast is NOT an observable fault
# for the registered workloads — the probe already re-inserts the address
# into every actual sharer's PA at acquire time, and non-sharers never
# later local-acquire these locks (verified while building this module:
# the workload checks stay green under that injection).  The observable
# limiting case of a lossy PA table is promotion starvation at the
# acquire, which `no_promotion` injects and every workload's check
# catches (tests/test_workloads.py).
