"""Work-stealing load balancing — the first registered workload.

This is the paper's evaluation harness (§5.1): a lock-free-style
work-stealing runtime (Cederman & Tsigas [10]) where each work-group owns a
task queue; owners dequeue from the tail with *local-scope* synchronization
and thieves steal from the head with *remote-scope* (or global-scope)
synchronization.  Queue words — lock, head, tail, task entries — live inside
the protocol's simulated memory, so a protocol bug produces stale task ids /
lost or duplicated chunks, which the harness detects (``proc_errors``).

Five scenarios (paper §5.1):
    baseline     no stealing, global-scope sync on every queue op
    scope_only   no stealing, local-scope sync (cheap but imbalanced)
    steal_only   stealing with global-scope sync everywhere
    rsp          local sync for owners; original flush-all/inv-all RSP
                 promotion for steals
    srsp         local sync for owners; this paper's selective promotion

Tasks are chunks of graph nodes; per-chunk work cycles follow the cost
model (task_base + per_edge * chunk_edges) and chunk outputs are written
through the simulated memory so flush traffic is real.

Scheduling is delegated to the workload-agnostic harness
(`workloads/harness.py`, extracted from this module verbatim — DESIGN.md
§4/§7): pop turns of distinct owners commute (`local_turn`), steals
serialize (`remote_turn`), and the batched engine's fence uses `rem` —
the summed base work still queued per owner — as the lower bound before
an owner can turn thief.  Counters and solutions are bitwise identical
between engines and unchanged from the pre-extraction engine
(tests/test_engine_equivalence.py).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import ops as O
from repro.core import protocol as P
from repro.core import costmodel, sfifo, tables
from repro.data.graphs import CSRGraph, collab_like
from repro.workloads import harness

QMETA = 16  # words reserved at the head of each queue (lock/head/tail block)

VMAPPABLE = False   # init_state enqueues host-side (numpy chunking)


@dataclasses.dataclass(frozen=True)
class WSConfig:
    n_wgs: int = 64
    chunk_cap: int = 32          # nodes per task chunk
    n_chunks_max: int = 512      # static bound on chunks per iteration
    fifo_cap: int = 16
    lr_tbl: tables.TableGeometry = tables.LR_GEOMETRY
    pa_tbl: tables.TableGeometry = tables.PA_GEOMETRY
    cold_factor: float = 1.0     # refill penalty scale after an invalidation
    params: costmodel.CostParams = dataclasses.field(default_factory=costmodel.CostParams)

    @property
    def qcap(self) -> int:
        return self.n_chunks_max  # worst-case skew bound

    @property
    def qstride(self) -> int:
        s = QMETA + self.qcap
        return (s + 15) // 16 * 16

    @property
    def data_base(self) -> int:
        return self.n_wgs * self.qstride

    @property
    def n_words(self) -> int:
        w = self.data_base + self.n_chunks_max * self.chunk_cap
        return (w + 15) // 16 * 16

    def proto_cfg(self) -> P.ProtoConfig:
        return P.ProtoConfig(n_caches=self.n_wgs, n_words=self.n_words,
                             fifo_cap=self.fifo_cap, lr_tbl=self.lr_tbl,
                             pa_tbl=self.pa_tbl, params=self.params)


# name -> (protocol, steal?).  A registry: unknown scenario names raise
# with the registered list instead of a bare KeyError.
SCENARIOS = P.Registry("worksteal scenario")
SCENARIOS.update({
    "baseline":   ("global", False),
    "scope_only": ("local", False),
    "steal_only": ("global", True),
    "rsp":        ("rsp", True),
    "srsp":       ("srsp", True),
})


class SimState(NamedTuple):
    store: P.Store
    qsize: jnp.ndarray      # [n_wgs] i32 bookkeeping occupancy
    processed: jnp.ndarray  # [n_chunks_max] i32 — from values read THROUGH the store
    last_inv: jnp.ndarray   # [n_wgs] f32 inv_per_cache snapshot at last processed chunk
    rounds: jnp.ndarray     # [] i32
    rem: jnp.ndarray        # [n_wgs] f32 Σ base work of chunks still in queue —
                            # a lower bound on cycles before this wg can steal
                            # (drives the batched scheduler's fence, DESIGN.md §4)


ENGINES = ("batched", "serial", "fused")


# --------------------------------------------------------------------------
# workload spec functions (module-level so Workloads hash/compare by value)
# --------------------------------------------------------------------------

def _max_events(ws: WSConfig) -> int:
    return 2 * ws.n_chunks_max + 4 * ws.n_wgs


def _can_pop(wl, s: SimState, chunk_count, chunk_edges):
    return s.qsize > 0


def _can_steal(wl, s: SimState, chunk_count, chunk_edges):
    if not wl.has_remote:
        return jnp.zeros_like(s.qsize, bool)
    return (s.qsize == 0) & (jnp.sum(s.qsize) > 0)


def _steal_bound(wl, s: SimState, chunk_count, chunk_edges):
    return s.rem


def _live(wl, s: SimState, chunk_count, chunk_edges):
    return (jnp.sum(s.qsize) > 0) & (s.rounds < _max_events(wl.cfg))


def _steal_or_idle_turn(wl, state: SimState, wg, chunk_count, chunk_edges
                        ) -> SimState:
    """One serial turn for a work-group with an empty queue: steal from the
    fullest victim (remote-scope sync) or idle.  Steals broadcast probes /
    flushes to other caches, so they never batch (DESIGN.md §4)."""
    ws, proto = wl.cfg, wl.proto
    cfg = ws.proto_cfg()
    p = cfg.params
    sizes_others = state.qsize.at[wg].set(0)
    victim = jnp.argmax(sizes_others).astype(jnp.int32)
    can_steal = jnp.asarray(wl.has_remote) & (sizes_others[victim] > 0)

    def do_steal(st):
        lock = victim * ws.qstride
        hot = harness.one_hot(ws.n_wgs, wg)
        st, oldv = O.acquire(proto, cfg, st, hot, lock, 0, 1, scope=O.REMOTE)
        # lock-sensitive: a steal that loses the CAS takes nothing and
        # leaves the queue intact.  Healthy runs never lose it — turns are
        # atomic, so every lock is free between turns — but a crashed
        # owner's stuck lock (faults.crash_holding_lock) fences thieves
        # out until the recovery drain force-releases it (DESIGN.md §10).
        got = oldv[wg] == 0
        st, head = P.load(cfg, st, wg, lock + 1)
        st, tail = P.load(cfg, st, wg, lock + 2)
        has = got & (head < tail)
        slot = jnp.clip(head, 0, ws.qcap - 1)
        st, task = P.load(cfg, st, wg, lock + QMETA + slot)
        st, _ = P.store_word(cfg, st, wg, lock + 1, head + 1, guard=has)
        st = O.release(proto, cfg, st, hot & got, lock, 0, scope=O.REMOTE)
        c = st.counters
        st = st._replace(counters=c._replace(
            steals=c.steals + has.astype(jnp.float32)))
        return st, jnp.where(has, task - 1, -1)

    def do_idle(st):
        return st, jnp.int32(-1)

    store, chunk = lax.cond(can_steal, do_steal, do_idle, state.store)
    # bookkeeping shrinks only on an actual take (chunk >= 0): a lock-fenced
    # steal must not hide the stuck chunks from future thieves
    qsize = state.qsize.at[victim].add(jnp.where(can_steal & (chunk >= 0),
                                                 -1, 0))
    qsize = jnp.maximum(qsize, 0)

    # ------- process the stolen chunk (thief pays, victim's queue shrinks) --
    valid = (chunk >= 0) & (chunk < ws.n_chunks_max)
    safe = jnp.clip(chunk, 0, ws.n_chunks_max - 1)
    processed = state.processed.at[safe].add(valid.astype(jnp.int32))
    count = jnp.where(valid, chunk_count[safe], 0)
    edges = jnp.where(valid, chunk_edges[safe], 0.0)
    base_work = p.task_base + p.per_edge * edges
    # the stolen chunk leaves the victim's queue: maintain the remaining-work
    # lower bound the batched scheduler fences on
    rem = state.rem.at[victim].add(-jnp.where(valid, base_work, 0.0))
    rem = jnp.maximum(rem, 0.0)
    # cold-cache refill penalty if the thief's L1 was invalidated since its
    # last chunk (models the post-invalidate miss storm, DESIGN.md §2)
    inv_now = store.counters.inv_per_cache[wg]
    was_cold = inv_now > state.last_inv[wg]
    touched_lines = count.astype(jnp.float32) + edges / 4.0
    work = base_work + jnp.where(was_cold, ws.cold_factor
                                 * touched_lines * (p.l2_lat / 4.0), 0.0)
    c = store.counters
    c = c._replace(cycles=c.cycles.at[wg].add(jnp.where(valid, work, 0.0)))
    store = store._replace(counters=c)
    last_inv = state.last_inv.at[wg].set(
        jnp.where(valid, inv_now, state.last_inv[wg]))

    # chunk output writes go through the memory system (flushable dirt)
    dblk = ws.chunk_cap // 16 + 1

    def wr(st, kk):
        a = ws.data_base + safe * ws.chunk_cap + kk * 16
        g = valid & ((kk * 16) < count)
        st, _ = P.store_word(cfg, st, wg, jnp.clip(a, 0, cfg.n_words - 1),
                             chunk, guard=g)
        return st, None

    store, _ = lax.scan(wr, store, jnp.arange(dblk, dtype=jnp.int32))
    return SimState(store, qsize, processed, last_inv, state.rounds + 1, rem)


def _pop_batch_turn(wl, state: SimState, mask, chunk_count, chunk_edges
                    ) -> SimState:
    """Execute one pop turn for every work-group in `mask` at once.
    Identical per-lane op order to the serial pop branch; every op is a
    masked multi-cache protocol op, so a batch of k pops costs one set of
    array ops instead of k while-loop trips."""
    ws, proto = wl.cfg, wl.proto
    cfg = ws.proto_cfg()
    p = cfg.params
    n = ws.n_wgs
    wgs = jnp.arange(n, dtype=jnp.int32)
    locks = wgs * ws.qstride

    st = state.store
    st, oldv = O.acquire(proto, cfg, st, mask, locks, 0, 1, scope=O.LOCAL)
    # lock-sensitive pops (see _steal_or_idle_turn): a lane that loses its
    # own-queue CAS — impossible healthy, real once a crash strands the
    # lock at 1 — takes nothing and releases nothing
    got = mask & (oldv == 0)
    st, tail = O.load(cfg, st, mask, locks + 2)
    st, head = O.load(cfg, st, mask, locks + 1)
    has = got & (head < tail)
    slot = jnp.clip(tail - 1, 0, ws.qcap - 1)
    st, task = O.load(cfg, st, mask, locks + QMETA + slot)
    st, _ = O.store(cfg, st, has, locks + 2, tail - 1)
    st = O.release(proto, cfg, st, got, locks, 0, scope=O.LOCAL)
    chunk = jnp.where(has, task - 1, -1)

    qsize = jnp.maximum(state.qsize - has.astype(jnp.int32), 0)

    # ------- process the chunks -------
    valid = (chunk >= 0) & (chunk < ws.n_chunks_max)
    safe = jnp.clip(chunk, 0, ws.n_chunks_max - 1)
    processed = state.processed.at[safe].add(valid.astype(jnp.int32))
    count = jnp.where(valid, chunk_count[safe], 0)
    edges = jnp.where(valid, chunk_edges[safe], 0.0)
    base_work = p.task_base + p.per_edge * edges
    rem = jnp.maximum(state.rem - jnp.where(valid, base_work, 0.0), 0.0)
    inv_now = st.counters.inv_per_cache
    was_cold = inv_now > state.last_inv
    touched_lines = count.astype(jnp.float32) + edges / 4.0
    work = base_work + jnp.where(was_cold, ws.cold_factor * touched_lines
                                 * (p.l2_lat / 4.0), 0.0)
    c = st.counters
    c = c._replace(cycles=c.cycles + jnp.where(valid, work, 0.0))
    st = st._replace(counters=c)
    last_inv = jnp.where(valid, inv_now, state.last_inv)

    # chunk output writes go through the memory system (flushable dirt)
    dblk = ws.chunk_cap // 16 + 1
    for kk in range(dblk):
        a = ws.data_base + safe * ws.chunk_cap + kk * 16
        g = valid & ((kk * 16) < count)
        st, _ = P.b_store_word(cfg, st, g,
                               jnp.clip(a, 0, cfg.n_words - 1), chunk)
    rounds = state.rounds + jnp.sum(mask.astype(jnp.int32))
    return SimState(st, qsize, processed, last_inv, rounds, rem)


def build_workload(ws: WSConfig, proto: P.Protocol, steal: bool
                   ) -> harness.Workload:
    """Bind the work-steal spec: pops commute, steals serialize, `rem`
    fences future thieves (DESIGN.md §4)."""
    return harness.Workload(
        name="worksteal", cfg=ws, proto=proto, has_remote=steal,
        can_local=_can_pop, can_remote=_can_steal,
        local_turn=_pop_batch_turn, remote_turn=_steal_or_idle_turn,
        remote_bound=_steal_bound, live=_live)


class WorkStealSim:
    """Round-based simulator for one scenario.

    The jit-compiled programs live at module level with *fine-grained*
    static keys, so they are shared wherever the traced computation is
    identical: two sims with the same WSConfig share the enqueue program
    whenever their owner-side protocol matches (srsp/rsp/scope_only all use
    local-scope owners; baseline/steal_only use global), across instances,
    apps and engines."""

    def __init__(self, ws: WSConfig, scenario: str, engine: str = "batched"):
        if scenario not in SCENARIOS:
            raise ValueError(f"unknown scenario {scenario!r}; "
                             f"registered: {sorted(SCENARIOS)}")
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; "
                             f"registered: {sorted(ENGINES)}")
        self.ws = ws
        self.scenario = scenario
        self.engine = engine
        proto_name, steal = SCENARIOS[scenario]
        self.proto = P.get_protocol(proto_name)
        self.steal = steal
        self.cfg = ws.proto_cfg()
        self._enqueue = partial(_enqueue_jit, ws, self.proto.acquire_loc_b,
                                self.proto.release_loc_b)
        self.workload = build_workload(ws, self.proto, steal)
        self._run_rounds = partial(harness.runner(engine), self.workload)

    def make_store(self) -> P.Store:
        return P.make_store(self.cfg)

    # ---------------- per-iteration driver ----------------
    def run_iteration(self, store: P.Store, frontier_nodes: np.ndarray,
                      degrees: np.ndarray, last_inv: jnp.ndarray):
        """Distribute `frontier_nodes` as chunks, enqueue, run rounds.

        Returns (store', last_inv', proc_errors, n_chunks)."""
        ws = self.ws
        n = len(degrees)
        plan = _chunk_plan(ws, frontier_nodes, degrees,
                           # ownership by node range
                           lambda c, sel, nc: int(sel[0]) * ws.n_wgs // n)
        store = self._enqueue(store, jnp.asarray(plan.owner),
                              jnp.asarray(plan.slot), jnp.asarray(plan.valid),
                              jnp.asarray(plan.n_enq))
        state = SimState(store=store, qsize=jnp.asarray(plan.n_enq),
                         processed=jnp.zeros(ws.n_chunks_max, jnp.int32),
                         last_inv=last_inv, rounds=jnp.int32(0),
                         rem=jnp.asarray(plan.rem))
        state = self._run_rounds(state, jnp.asarray(plan.count),
                                 jnp.asarray(plan.edges))
        proc = np.asarray(state.processed)
        errors = int(np.abs(proc[plan.valid] - 1).sum()
                     + proc[~plan.valid].sum())
        return state.store, state.last_inv, errors, plan.n_chunks


class ChunkPlan(NamedTuple):
    owner: np.ndarray
    slot: np.ndarray
    count: np.ndarray
    edges: np.ndarray   # f32
    valid: np.ndarray
    n_enq: np.ndarray
    rem: np.ndarray     # f32 per-owner Σ base work (the batched fence bound)
    n_chunks: int


def _chunk_plan(ws: WSConfig, frontier_nodes: np.ndarray, degrees: np.ndarray,
                owner_of) -> ChunkPlan:
    """Host-side chunking shared by run_iteration and the Bench contract;
    `owner_of(c, sel, n_chunks)` is the ownership policy."""
    nf = len(frontier_nodes)
    n_chunks = min((nf + ws.chunk_cap - 1) // ws.chunk_cap, ws.n_chunks_max)
    owner = np.zeros(ws.n_chunks_max, np.int32)
    count = np.zeros(ws.n_chunks_max, np.int32)
    edges = np.zeros(ws.n_chunks_max, np.float32)
    valid = np.zeros(ws.n_chunks_max, bool)
    for c in range(n_chunks):
        sel = frontier_nodes[c * ws.chunk_cap:(c + 1) * ws.chunk_cap]
        owner[c] = owner_of(c, sel, n_chunks)
        count[c] = len(sel)
        edges[c] = float(degrees[sel].sum())
        valid[c] = True
    # slot index within owner's queue
    slot = np.zeros(ws.n_chunks_max, np.int32)
    n_enq = np.zeros(ws.n_wgs, np.int32)
    for c in range(n_chunks):
        slot[c] = n_enq[owner[c]]
        n_enq[owner[c]] += 1
    p = ws.params
    # f32 arithmetic to match the engine's per-pop decrements exactly
    base_work = np.where(valid, np.float32(p.task_base)
                         + np.float32(p.per_edge) * edges, np.float32(0))
    rem = np.zeros(ws.n_wgs, np.float32)
    np.add.at(rem, owner, base_work.astype(np.float32))
    return ChunkPlan(owner, slot, count, edges.astype(np.float32), valid,
                     n_enq, rem, n_chunks)


# ---------------- enqueue (batch, one critical section per owner) ----------
@partial(jax.jit, static_argnums=(0, 1, 2),
         **({"donate_argnums": (3,)} if harness.DONATE else {}))
def _enqueue_jit(ws: WSConfig, oacq_b, orel_b, store: P.Store, enq_owner,
                 enq_slot, enq_valid, n_enq):
    """All owners enqueue at once: each work-group's critical section
    touches only its own queue words and its own cache, so every owner-side
    op runs as one masked multi-cache op.  The task-word sFIFO `touch` walk
    is a scan over *block offsets* (a handful) with all work-groups pushing
    in lockstep, not a scan over work-groups.

    Static key = (config, LOCAL-scope acquire/release table entries):
    scenarios whose protocols share the local-scope realization share
    this compiled program (srsp/rsp/scope_only, and baseline/steal_only),
    which a full-Protocol key would needlessly split."""
    cfg = ws.proto_cfg()
    n = ws.n_wgs
    W = cfg.block_words
    chunk_ids = jnp.arange(ws.n_chunks_max, dtype=jnp.int32)
    max_blk = ws.qcap // 16 + 2
    wgs = jnp.arange(n, dtype=jnp.int32)
    locks = wgs * ws.qstride
    every = jnp.ones((n,), bool)

    # acquire FIRST: a promoted acquire invalidates this cache, so
    # the task-word writes must land inside the critical section
    # (writing before the acquire broke the dirty⊆sFIFO invariant
    # and produced stale task reads — see tests/test_worksteal.py)
    st, _ = oacq_b(cfg, store, every, locks, 0, 1)
    # scatter every wg's task words (write-combining bulk store)
    addr = jnp.where(enq_valid, enq_owner * ws.qstride + QMETA + enq_slot,
                     jnp.int32(cfg.n_blocks * W))  # out of range -> drop
    ab, ao = addr // W, addr % W
    st = st._replace(
        l1=st.l1.at[enq_owner, ab, ao].set(chunk_ids + 1, mode="drop"),
        wvalid=P.plane_scatter_set(st.wvalid, enq_owner, ab, ao),
        wdirty=P.plane_scatter_set(st.wdirty, enq_owner, ab, ao))
    # record the task-word blocks in the sFIFO (write-combining path)
    first_blk = (locks + QMETA) // W
    no_tail = jnp.zeros((n,), bool)

    def touch(st, i):
        guard = (i * W) < n_enq
        f2, evicted, _ = jax.vmap(sfifo.push)(st.fifo, first_blk + i, no_tail)
        st = st._replace(fifo=P._mask_tree_rows(guard, f2, st.fifo))
        evicted = jnp.where(guard, evicted, jnp.int32(-1))
        st, _ = P.b_writeback(cfg, st, evicted, evicted >= 0)
        return st, None

    st, _ = lax.scan(touch, st, jnp.arange(max_blk, dtype=jnp.int32))
    st, _ = P.b_store_word(cfg, st, every, locks + 1, jnp.zeros((n,), jnp.int32))
    st, _ = P.b_store_word(cfg, st, every, locks + 2, n_enq)
    st = orel_b(cfg, st, every, locks, 0)
    c = st.counters
    c = c._replace(cycles=c.cycles
                   + n_enq.astype(jnp.float32) * cfg.params.l1_lat)
    return st._replace(counters=c)


# --------------------------------------------------------------------------
# applications (paper §5.1: PageRank, SSSP; MIS also mentioned)
# --------------------------------------------------------------------------

class AppResult(NamedTuple):
    name: str
    scenario: str
    makespan: float
    counters: dict
    proc_errors: int
    iterations: int
    wall_s: float
    solution: np.ndarray


def _edge_arrays(g: CSRGraph):
    rows = np.repeat(np.arange(g.n, dtype=np.int32), g.degrees)
    return rows, g.indices, g.weights


def run_app(app: str, g: CSRGraph, scenario: str, ws: WSConfig,
            max_iters: int = 8, seed: int = 0,
            engine: str = "batched") -> AppResult:
    sim = WorkStealSim(ws, scenario, engine)
    store = sim.make_store()
    last_inv = jnp.zeros((ws.n_wgs,), jnp.float32)
    rows, cols, w = _edge_arrays(g)
    rows_j, cols_j, w_j = jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(w)
    deg = jnp.asarray(np.maximum(g.degrees, 1))
    n = g.n
    t0 = time.perf_counter()
    errors = 0
    iters = 0

    if app == "pagerank":
        ranks = jnp.full((n,), 1.0 / n, jnp.float32)

        @jax.jit
        def bulk(r):
            contrib = r[cols_j] / deg[cols_j]
            s = jnp.zeros((n,), jnp.float32).at[rows_j].add(contrib)
            return 0.15 / n + 0.85 * s

        frontier = np.arange(n, dtype=np.int32)
        for it in range(max_iters):
            store, last_inv, e, _ = sim.run_iteration(store, frontier,
                                                      g.degrees, last_inv)
            errors += e
            ranks = bulk(ranks)
            iters += 1
        solution = np.asarray(ranks)

    elif app == "sssp":
        INF = np.int32(2**30)
        dist = jnp.full((n,), INF, jnp.int32).at[0].set(0)

        @jax.jit
        def bulk(d, fmask):
            cand = d[rows_j] + w_j
            cand = jnp.where(fmask[rows_j], cand, INF)
            nd = d.at[cols_j].min(cand)
            return nd, nd < d

        frontier_mask = np.zeros(n, bool)
        frontier_mask[0] = True
        dist_j = dist
        for it in range(max_iters):
            fnodes = np.nonzero(frontier_mask)[0].astype(np.int32)
            if len(fnodes) == 0:
                break
            store, last_inv, e, _ = sim.run_iteration(store, fnodes,
                                                      g.degrees, last_inv)
            errors += e
            dist_j, improved = bulk(dist_j, jnp.asarray(frontier_mask))
            frontier_mask = np.asarray(improved)
            iters += 1
        solution = np.asarray(dist_j)

    elif app == "mis":
        # Luby's algorithm: 0 undecided / 1 in MIS / 2 excluded
        status = jnp.zeros((n,), jnp.int32)
        key = jax.random.PRNGKey(seed)

        @jax.jit
        def bulk(st, k):
            und = st == 0
            prio = jax.random.uniform(k, (n,)) + jnp.where(und, 0.0, -10.0)
            nb_max = jnp.full((n,), -20.0).at[rows_j].max(
                jnp.where(und[cols_j], prio[cols_j], -20.0))
            join = und & (prio > nb_max)
            st = jnp.where(join, 1, st)
            excl = jnp.zeros((n,), bool).at[rows_j].max(join[cols_j])
            st = jnp.where((st == 0) & excl, 2, st)
            return st

        for it in range(max_iters * 3):
            und_nodes = np.nonzero(np.asarray(status) == 0)[0].astype(np.int32)
            if len(und_nodes) == 0:
                break
            store, last_inv, e, _ = sim.run_iteration(store, und_nodes,
                                                      g.degrees, last_inv)
            errors += e
            key, sub = jax.random.split(key)
            status = bulk(status, sub)
            iters += 1
        solution = np.asarray(status)
    else:
        raise ValueError(f"unknown app {app!r}")

    wall = time.perf_counter() - t0
    counters = harness.counters_dict(store)
    return AppResult(app, scenario, counters["makespan"], counters, errors,
                     iters, wall, solution)


def reference_solution(app: str, g: CSRGraph, max_iters: int = 8,
                       seed: int = 0) -> np.ndarray:
    """Single-threaded oracle — identical bulk math, no scheduler/protocol."""
    rows, cols, w = _edge_arrays(g)
    n = g.n
    deg = np.maximum(g.degrees, 1)
    if app == "pagerank":
        r = np.full(n, 1.0 / n, np.float32)
        for _ in range(max_iters):
            s = np.zeros(n, np.float32)
            np.add.at(s, rows, r[cols] / deg[cols])
            r = (0.15 / n + 0.85 * s).astype(np.float32)
        return r
    if app == "sssp":
        INF = np.int64(2**30)
        d = np.full(n, INF, np.int64)
        d[0] = 0
        fmask = np.zeros(n, bool)
        fmask[0] = True
        for _ in range(max_iters):
            if not fmask.any():
                break
            cand = np.where(fmask[rows], d[rows] + w, INF)
            nd = d.copy()
            np.minimum.at(nd, cols, cand)
            fmask = nd < d
            d = nd
        return d.astype(np.int32)
    if app == "mis":
        # same PRNG sequence as run_app's bulk
        status = jnp.zeros((n,), jnp.int32)
        key = jax.random.PRNGKey(seed)
        rows_j, cols_j = jnp.asarray(rows), jnp.asarray(cols)

        @jax.jit
        def bulk(st, k):
            und = st == 0
            prio = jax.random.uniform(k, (n,)) + jnp.where(und, 0.0, -10.0)
            nb_max = jnp.full((n,), -20.0).at[rows_j].max(
                jnp.where(und[cols_j], prio[cols_j], -20.0))
            join = und & (prio > nb_max)
            st = jnp.where(join, 1, st)
            excl = jnp.zeros((n,), bool).at[rows_j].max(join[cols_j])
            st = jnp.where((st == 0) & excl, 2, st)
            return st

        for _ in range(max_iters * 3):
            if not (np.asarray(status) == 0).any():
                break
            key, sub = jax.random.split(key)
            status = bulk(status, sub)
        return np.asarray(status)
    raise ValueError(app)


# --------------------------------------------------------------------------
# registry contract (workloads/__init__.py): build / init_state / self_check
# --------------------------------------------------------------------------

Bench = harness.Bench


def build(scenario: str, n_agents: int, seed: int = 0, *,
          proto: P.Protocol = None, **kw) -> harness.Bench:
    """Standard-contract bench: a one-iteration work-steal round over a
    synthetic collab graph sized so queues start half-full (steals happen).
    `proto` overrides the scenario's protocol table (fault injection)."""
    _, steal = SCENARIOS[scenario]
    p = harness.resolve_proto(scenario, proto)
    kw.setdefault("chunk_cap", 8)
    kw.setdefault("n_chunks_max", max(2 * n_agents, 8))
    ws = WSConfig(n_wgs=n_agents, **kw)
    g = collab_like(n=ws.n_chunks_max * ws.chunk_cap // 2, m=3,
                    seed=1 + seed)
    wl = build_workload(ws, p, steal)

    frontier = np.arange(g.n, dtype=np.int32)
    # skewed ownership: agent 0 owns half the chunks, the rest spread
    # round-robin — guarantees the imbalance that makes steals happen
    plan = _chunk_plan(ws, frontier, g.degrees,
                       lambda c, sel, nc: 0 if c < nc // 2 else c % ws.n_wgs)
    store = _enqueue_jit(ws, p.acquire_loc_b, p.release_loc_b,
                         P.make_store(ws.proto_cfg()),
                         jnp.asarray(plan.owner), jnp.asarray(plan.slot),
                         jnp.asarray(plan.valid), jnp.asarray(plan.n_enq))
    state = SimState(store=store, qsize=jnp.asarray(plan.n_enq),
                     processed=jnp.zeros(ws.n_chunks_max, jnp.int32),
                     last_inv=jnp.zeros((ws.n_wgs,), jnp.float32),
                     rounds=jnp.int32(0), rem=jnp.asarray(plan.rem))
    ops = (jnp.asarray(plan.count), jnp.asarray(plan.edges))

    def check(final: SimState) -> dict:
        proc = np.asarray(final.processed)
        fails = int(np.abs(proc[plan.valid] - 1).sum()
                    + proc[~plan.valid].sum())
        return {"ok": fails == 0, "check_fails": fails,
                "events": int(final.rounds)}

    return Bench(wl, state, ops, check)
