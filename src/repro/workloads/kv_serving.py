"""KV serving tier — trace-driven hot-page ownership under Zipf skew.

The ROADMAP's "millions of users" workload: an LLM serving tier where
every cache owns a shard of hot KV pages (`serve/engine.py`'s slot
cache, scaled out to n_agents shards) and requests arrive from the
traffic subsystem (DESIGN.md §13) instead of a self-driven quota —
Zipf-skewed key popularity, Poisson/bursty arrivals, configurable
read/write mix.  Each agent serves its stream in arrival order:

  * local turns (the hot path): the owner serves a request for one of
    its OWN pages — wait for the arrival clock, acquire the page lock
    at LOCAL scope, read the value THROUGH the store (stale-read
    check), apply the write if the request is one, release, charge
    `task_cost` serving compute.  Ownership partitions the pages, so
    local turns of distinct agents commute (§4 obligation).
  * remote turns (the rare path): a cross-owner lookup of a hot page —
    remote acquire, read version+value through the store, compare
    against bookkept ground truth, release.  Concurrent lookups target
    their requests' pages; the harness's address dedup (§9) co-schedules
    distinct-page lookups in one masked turn.  A lookup whose CAS loses
    (only possible when a fault strands a lock) RETRIES: the cursor
    stays, the lane tries again next turn — so a crash-stranded lock
    shows up as `done=False`, never as silent corruption.
  * per-request completion latency (completion clock − arrival clock)
    accumulates into a state-resident log2 histogram — the same bucket
    math as the §11 trace — so `latency_p50/p95/p99` fill from the
    *request* distribution even with tracing compiled off.

Self-checks: in-run stale-read fails + offered-vs-completed accounting
+ post-run drained-L2 audit of every page (no lost pages, no stale
reads).  The schedule depends only on the trace and bookkeeping, never
on store reads, so a protocol bug changes checked values — not turns.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import ops as O
from repro.core import protocol as P
from repro.core import tables
from repro.core.costmodel import CostParams
from repro.obs import metrics
from repro.traffic import driver as D
from repro.traffic import samplers as S
from repro.traffic import trace as TR
from repro.workloads import harness

VMAPPABLE = True


@dataclasses.dataclass(frozen=True)
class Config:
    n_agents: int = 8
    pages_per_agent: int = 2
    traffic: S.TrafficConfig = S.TrafficConfig()
    task_cost: float = 20.0      # serving compute per completed request
    fifo_cap: int = 16
    lr_tbl: tables.TableGeometry = tables.LR_GEOMETRY
    pa_tbl: tables.TableGeometry = tables.PA_GEOMETRY
    params: CostParams = dataclasses.field(default_factory=CostParams)

    @property
    def n_pages(self) -> int:
        return self.n_agents * self.pages_per_agent

    @property
    def bstride(self) -> int:
        return 16   # lock / version / value in one block

    @property
    def n_words(self) -> int:
        return self.n_pages * self.bstride

    def proto_cfg(self) -> P.ProtoConfig:
        return P.ProtoConfig(n_caches=self.n_agents, n_words=self.n_words,
                             fifo_cap=self.fifo_cap, lr_tbl=self.lr_tbl,
                             pa_tbl=self.pa_tbl, params=self.params)


class ServeState(NamedTuple):
    store: P.Store
    streams: D.AgentStreams   # [n, m] request columns (traffic driver)
    cursor: jnp.ndarray       # [n] i32 completed requests per agent
    ver: jnp.ndarray          # [n_pages] i32 bookkeeping: true version
    val: jnp.ndarray          # [n_pages] i32 bookkeeping: true value
    lat_hist: jnp.ndarray     # [metrics.N_BUCKETS] i32 request latencies
    check_fails: jnp.ndarray  # [] i32
    rounds: jnp.ndarray       # [] i32


def _max_events(cfg: Config) -> int:
    # healthy: one turn per request; slack covers fault-injected retries
    return cfg.n_agents * (cfg.traffic.requests_per_agent + 16) \
        + 16 * cfg.n_agents


def _lanes(cfg: Config):
    return jnp.arange(cfg.n_agents, dtype=jnp.int32)


def _charge_wait(st: P.Store, mask, streams, cursor) -> P.Store:
    """Idle until the masked lanes' next requests have arrived."""
    wait = D.wait_cycles(streams, cursor, st.counters.cycles)
    c = st.counters
    return st._replace(counters=c._replace(
        cycles=c.cycles + jnp.where(mask, wait, 0.0)))


def _note_latency(lat_hist, st: P.Store, mask, streams, cursor):
    """Completion latency (now − arrival) of the masked lanes' requests,
    bucketed with the §11 log2 edges."""
    arr, _, _, _ = D.at_cursor(streams, cursor)
    lat = jnp.maximum(st.counters.cycles - arr, 0.0)
    idx = metrics.bucket_index(jnp.where(mask, lat, 0.0))
    return lat_hist.at[idx].add(mask.astype(jnp.int32))


def _can_local(wl, s: ServeState):
    return D.can_local(s.streams, s.cursor)


def _can_remote(wl, s: ServeState):
    return D.can_remote(s.streams, s.cursor)


def _remote_bound(wl, s: ServeState):
    return D.remote_bound(s.streams, s.cursor, wl.cfg.task_cost)


def _remote_addr(wl, s: ServeState):
    _, page, _, _ = D.at_cursor(s.streams, s.cursor)
    return page * jnp.int32(wl.cfg.bstride)


def _live(wl, s: ServeState):
    return jnp.any(D.pending(s.streams, s.cursor)) \
        & (s.rounds < _max_events(wl.cfg))


def _retire(wl, s: ServeState, dead, *ops) -> ServeState:
    """Elastic retirement (§10): a dead shard's unserved tail is
    forgiven; its pages keep their bookkept ground truth so the post-run
    audit still scores every committed write."""
    return s._replace(streams=D.retire(s.streams, s.cursor, dead))


def _admit(wl, s: ServeState, join, *ops) -> ServeState:
    return s._replace(streams=D.admit(s.streams, s.cursor, join))


def _delta(lanes, cursor, page):
    return (lanes + 1) + jnp.mod(cursor * 7 + page, jnp.int32(5))


def _local_turn(wl, s: ServeState, mask) -> ServeState:
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    lanes = _lanes(cfg)
    np_ = cfg.n_pages
    _, page, kind, _ = D.at_cursor(s.streams, s.cursor)
    lockp = page * cfg.bstride
    delta = _delta(lanes, s.cursor, page)
    newval = s.val[page] + delta

    st = _charge_wait(s.store, mask, s.streams, s.cursor)
    st, old = O.acquire(wl.proto, pc, st, mask, lockp, 0, 1, scope=O.LOCAL)
    # a lost CAS (possible only when a fault strands a lock — healthy
    # runs always see 0) leaves the request in place for a retry turn
    ok = mask & (old == 0)
    st, vcur = O.load(pc, st, ok, lockp + 2)
    wr = ok & (kind == 1)
    st, _ = O.store(pc, st, wr, lockp + 2, newval)
    st, _ = O.store(pc, st, wr, lockp + 1, s.ver[page] + 1)
    st = O.release(wl.proto, pc, st, ok, lockp, 0, scope=O.LOCAL)
    st = harness.charge(st, ok, cfg.task_cost)

    fails = jnp.sum((ok & (vcur != s.val[page])).astype(jnp.int32))
    tgt = jnp.where(wr, page, np_)
    return ServeState(
        store=st,
        streams=s.streams,
        cursor=s.cursor + ok.astype(jnp.int32),
        ver=s.ver.at[tgt].add(1, mode="drop"),
        val=s.val.at[tgt].add(delta, mode="drop"),
        lat_hist=_note_latency(s.lat_hist, st, ok, s.streams, s.cursor),
        check_fails=s.check_fails + fails,
        rounds=s.rounds + jnp.sum(mask.astype(jnp.int32)))


def _remote_turn_b(wl, s: ServeState, rmask) -> ServeState:
    """Masked multi-agent cross-owner lookup (§9 capability): every
    masked lane resolves its request's page in one set of scoped ops.
    Distinct lanes' requests target distinct addresses by the harness's
    dedup, and a lookup mutates only its own lane's cursor/latency —
    the pairwise-commutation obligation."""
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    do = jnp.asarray(rmask, bool) & _can_remote(wl, s)
    _, page, _, _ = D.at_cursor(s.streams, s.cursor)
    lockp = page * cfg.bstride

    st = _charge_wait(s.store, do, s.streams, s.cursor)
    st, old = O.acquire(wl.proto, pc, st, do, lockp, 0, 1, scope=O.REMOTE)
    ok = do & (old == 0)      # lost CAS -> retry next turn (cursor stays)
    st, rv = O.load(pc, st, ok, lockp + 1)
    st, vv = O.load(pc, st, ok, lockp + 2)
    st = O.release(wl.proto, pc, st, ok, lockp, 0, scope=O.REMOTE)
    st = harness.charge(st, ok, cfg.task_cost)

    fails = jnp.sum(jnp.where(ok, (rv != s.ver[page]).astype(jnp.int32)
                              + (vv != s.val[page]).astype(jnp.int32), 0))
    return ServeState(
        store=st,
        streams=s.streams,
        cursor=s.cursor + ok.astype(jnp.int32),
        ver=s.ver, val=s.val,
        lat_hist=_note_latency(s.lat_hist, st, ok, s.streams, s.cursor),
        check_fails=s.check_fails + fails,
        rounds=s.rounds + jnp.sum(do.astype(jnp.int32)))


def _remote_turn(wl, s: ServeState, wg) -> ServeState:
    """Serializing reference turn — the one-hot batched turn."""
    return _remote_turn_b(wl, s, harness.one_hot(wl.cfg.n_agents, wg))


def build_workload(cfg: Config, proto: P.Protocol) -> harness.Workload:
    return harness.Workload(
        name="kv_serving", cfg=cfg, proto=proto, has_remote=True,
        can_local=_can_local, can_remote=_can_remote,
        local_turn=_local_turn, remote_turn=_remote_turn,
        remote_bound=_remote_bound, live=_live,
        remote_turn_b=_remote_turn_b, remote_addr=_remote_addr,
        retire=_retire, admit=_admit)


def init_state(wl, seed) -> ServeState:
    """Pure-jnp init (vmappable over `seed`): the whole request trace is
    regenerated from (seed, config) — the bitwise-replay contract."""
    cfg = wl.cfg
    tr = TR.generate(cfg.traffic, cfg.n_agents, cfg.n_pages, seed)
    streams = D.from_trace(tr, cfg.n_agents,
                           cfg.traffic.requests_per_agent)
    return ServeState(
        store=P.make_store(cfg.proto_cfg()),
        streams=streams,
        cursor=jnp.zeros((cfg.n_agents,), jnp.int32),
        ver=jnp.zeros((cfg.n_pages,), jnp.int32),
        val=jnp.zeros((cfg.n_pages,), jnp.int32),
        lat_hist=jnp.zeros((metrics.N_BUCKETS,), jnp.int32),
        check_fails=jnp.int32(0),
        rounds=jnp.int32(0))


def self_check(wl, final: ServeState) -> dict:
    """In-run stale reads + offered/completed accounting + drained-L2
    per-page audit, plus the request-latency histogram for the sweep's
    serving columns."""
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    fails = int(final.check_fails)
    cursor = np.asarray(final.cursor)
    quota = np.asarray(final.streams.quota)
    done = bool(np.all(cursor >= quota))
    st = harness.drain_all(pc, final.store)
    l2 = np.asarray(st.l2).reshape(-1)
    ver = np.asarray(final.ver)
    val = np.asarray(final.val)
    for p in range(cfg.n_pages):
        base = p * cfg.bstride
        fails += int(l2[base + 1] != ver[p]) + int(l2[base + 2] != val[p])
    hist = np.asarray(final.lat_hist, np.int64)
    lat = metrics.summarize(hist)
    offered = cfg.n_agents * cfg.traffic.requests_per_agent
    completed = int(cursor.sum())
    # completed requests carry exactly one latency sample each
    fails += int(lat["count"] != completed)
    return {"ok": fails == 0 and done, "check_fails": fails,
            "done": done, "events": int(final.rounds),
            "offered": offered, "completed": completed,
            "latency_hist": hist.tolist(), "latency": lat}


def build(scenario: str, n_agents: int, seed: int = 0, *,
          proto: P.Protocol = None, **kw) -> harness.Bench:
    return harness.make_bench(Config(n_agents=n_agents, **kw),
                              build_workload, init_state, self_check,
                              scenario, seed, proto)
