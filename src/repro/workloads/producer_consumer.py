"""Producer/consumer drains — remote consumers, many local producers.

Asymmetry shape: every producer appends items to its *own* ring region
with local-scope synchronization (the overwhelmingly common op);
`n_consumers` consumer agents periodically perform *remote-scope* drains
of producer regions (the rare op).  This is the inverse of
work-stealing's thief distribution — hot remote agents instead of many
occasional ones — and matches the one-sided access pattern of
RDMA-style asymmetric mutual exclusion (arXiv:2208.09540).

With `n_consumers = 1` (the default) this is the paper-shaped workload
whose single always-hot drainer IS the makespan under every protocol
(ROADMAP).  With `n_consumers > 1` the producers are *partitioned*:
producer p belongs to consumer ``p % n_consumers``, so concurrent drains
target pairwise-distinct locks and the workload can declare the
remote-batching capability (DESIGN.md §9) — the remote work itself
parallelizes, which is the ROADMAP follow-up this variant exists to
measure (registered as `producer_consumer_mc`).

Spec (DESIGN.md §7/§9):
  * local turns: producer i appends item `produced[i]` inside its own
    lock's critical section; a consumer burns a scratch turn (its own
    region) while its drain credit is positive.  All local turns touch
    pairwise-disjoint regions → they commute.
  * remote turn: consumer k remote-acquires its victim's lock (largest
    produced-consumed gap within its OWN partition), reads the count
    word and every fresh item THROUGH the store, and releases.  Victim
    choice and the consumed bookkeeping use host-invisible ground truth
    only, so the schedule is identical under a buggy protocol — the bug
    surfaces in the checked values, not as divergence.
  * remote batching obligations (§9): partitions are disjoint, so
    concurrent drains target distinct addresses whose sharer sets
    (exactly the victim producer) are disjoint; a drain resets only the
    drainer's own credit/consumed bookkeeping, so it never changes
    another consumer's capability or victim; and consumers hold no LR
    entries or foreign dirty words.  `remote_turn` is literally the
    one-hot instance of `remote_turn_b`, so serial and batched engines
    share one implementation.
  * fence: consumer k's next drain is at least `credit[k] · scratch_cost`
    cycles away (each scratch turn charges exactly that); producers
    never go remote (bound = BIG).
  * self-check: count word must equal the victim's true produced count
    at the drain's serial position; Σ item values read must equal the
    bookkept Σ expected; post-run, the drained L2 image must hold every
    item (lost-update audit).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import ops as O
from repro.core import protocol as P
from repro.core import tables
from repro.core.costmodel import CostParams
from repro.workloads import harness

VMAPPABLE = True


@dataclasses.dataclass(frozen=True)
class Config:
    n_agents: int = 8
    n_consumers: int = 1        # lanes [0, n_consumers) drain, rest produce
    max_items: int = 8          # static per-producer quota bound
    min_items: int = 4
    warmup: int = 3             # consumer scratch turns between drains
    scratch_cost: float = 20.0  # compute cycles charged per local turn
    fifo_cap: int = 16
    lr_tbl: tables.TableGeometry = tables.LR_GEOMETRY
    pa_tbl: tables.TableGeometry = tables.PA_GEOMETRY
    params: CostParams = dataclasses.field(default_factory=CostParams)

    @property
    def stride(self) -> int:
        return (2 + self.max_items + 15) // 16 * 16

    @property
    def n_words(self) -> int:
        return self.n_agents * self.stride

    def proto_cfg(self) -> P.ProtoConfig:
        return P.ProtoConfig(n_caches=self.n_agents, n_words=self.n_words,
                             fifo_cap=self.fifo_cap, lr_tbl=self.lr_tbl,
                             pa_tbl=self.pa_tbl, params=self.params)


class PCState(NamedTuple):
    store: P.Store
    produced: jnp.ndarray    # [n] i32 bookkeeping: items appended per producer
    consumed: jnp.ndarray    # [n] i32 bookkeeping: items drained per producer
    quota: jnp.ndarray       # [n] i32 per-producer target (0 for consumers)
    credit: jnp.ndarray      # [n] i32 per-consumer scratch turns before drain
    sum_seen: jnp.ndarray    # [] i32 Σ item values read THROUGH the store
    sum_expect: jnp.ndarray  # [] i32 Σ expected values of drained items
    check_fails: jnp.ndarray # [] i32 in-run consistency violations
    rounds: jnp.ndarray      # [] i32


def _item_val(agent, j):
    """Deterministic item payload — what the self-check replays."""
    return (jnp.asarray(agent, jnp.int32) + 1) * 131 \
        + 7 * jnp.asarray(j, jnp.int32) + 1


def _max_events(cfg: Config) -> int:
    return (cfg.warmup + 3) * cfg.n_agents * cfg.max_items + 4 * cfg.n_agents


def _lanes(cfg: Config):
    return jnp.arange(cfg.n_agents, dtype=jnp.int32)


def _is_consumer(cfg: Config):
    return _lanes(cfg) < cfg.n_consumers


def _own_live(wl, s: PCState):
    """Per-consumer: does my partition still have undrained quota?
    (Per-lane; meaningless for producer lanes.)"""
    cfg = wl.cfg
    lanes = _lanes(cfg)
    is_prod = lanes >= cfg.n_consumers
    open_ = is_prod & (s.consumed < s.quota)
    mine = open_[None, :] & (jnp.mod(lanes[None, :],
                                     jnp.int32(cfg.n_consumers))
                             == lanes[:, None])
    return jnp.any(mine, axis=1)


def _victims(wl, s: PCState):
    """Per-consumer victim: largest produced-consumed gap within own
    partition (bookkeeping only — protocol-bug-independent schedule)."""
    cfg = wl.cfg
    lanes = _lanes(cfg)
    is_prod = lanes >= cfg.n_consumers
    gap = jnp.where(is_prod, s.produced - s.consumed, -1)
    mine = is_prod[None, :] & (jnp.mod(lanes[None, :],
                                       jnp.int32(cfg.n_consumers))
                               == lanes[:, None])
    gm = jnp.where(mine, gap[None, :], -1)
    return jnp.argmax(gm, axis=1).astype(jnp.int32)


def _can_local(wl, s: PCState):
    cons = _is_consumer(wl.cfg)
    return jnp.where(cons, (s.credit > 0) & _own_live(wl, s),
                     s.produced < s.quota)


def _can_remote(wl, s: PCState):
    return _is_consumer(wl.cfg) & (s.credit == 0) & _own_live(wl, s)


def _remote_bound(wl, s: PCState):
    return jnp.where(_is_consumer(wl.cfg),
                     s.credit.astype(jnp.float32) * wl.cfg.scratch_cost,
                     harness.BIG)


def _remote_addr(wl, s: PCState):
    """Next drain's lock address per consumer lane (harness co-scheduling
    dedup input, DESIGN.md §9)."""
    return _victims(wl, s) * jnp.int32(wl.cfg.stride)


def _live(wl, s: PCState):
    return jnp.any(s.consumed < s.quota) & (s.rounds < _max_events(wl.cfg))


def _retire(wl, s: PCState, dead, *ops) -> PCState:
    """Elastic retirement (DESIGN.md §10): a dead producer stops owing
    items (quota := produced — its already-produced items still get
    drained and audited); a dead consumer orphans its partition, so those
    producers' undrained obligations are forgiven too (the post-run
    drain_all audit still checks every produced item at L2).  Bitwise
    identity when `dead` is all-False."""
    cfg = wl.cfg
    dead = jnp.asarray(dead, bool)
    cons = _is_consumer(cfg)
    orphan = ~cons & (dead & cons)[jnp.mod(_lanes(cfg),
                                           jnp.int32(cfg.n_consumers))]
    fold = (dead & ~cons) | orphan
    quota = jnp.where(fold, jnp.minimum(s.quota, s.produced), s.quota)
    consumed = jnp.where(orphan, jnp.maximum(s.consumed, quota), s.consumed)
    return s._replace(quota=quota, consumed=consumed)


def _admit(wl, s: PCState, join, *ops) -> PCState:
    """Elastic (re-)admission: a joining producer owes one more item
    (bounded by the static ring capacity)."""
    cfg = wl.cfg
    join = jnp.asarray(join, bool) & ~_is_consumer(cfg)
    quota = jnp.where(join,
                      jnp.minimum(s.produced + 1, jnp.int32(cfg.max_items)),
                      s.quota)
    return s._replace(quota=quota)


def _local_turn(wl, s: PCState, mask) -> PCState:
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    lanes = _lanes(cfg)
    cons = mask & _is_consumer(cfg)
    prod = mask & ~_is_consumer(cfg)
    locks = lanes * cfg.stride

    st = s.store
    # producers: append inside own critical section (LOCAL-scope sync)
    st, _ = O.acquire(wl.proto, pc, st, prod, locks, 0, 1, scope=O.LOCAL)
    slot = jnp.clip(s.produced, 0, cfg.max_items - 1)
    st, _ = O.store(pc, st, prod, locks + 2 + slot,
                    _item_val(lanes, s.produced))
    st, _ = O.store(pc, st, prod, locks + 1, s.produced + 1)
    st = O.release(wl.proto, pc, st, prod, locks, 0, scope=O.LOCAL)
    # consumers: scratch write in their own regions (write-combining dirt)
    st, _ = O.store(pc, st, cons,
                    locks + 2 + s.credit % jnp.int32(cfg.max_items),
                    s.credit)
    st = harness.charge(st, mask, cfg.scratch_cost)

    return PCState(
        store=st,
        produced=s.produced + prod.astype(jnp.int32),
        consumed=s.consumed,
        quota=s.quota,
        credit=s.credit - cons.astype(jnp.int32),
        sum_seen=s.sum_seen, sum_expect=s.sum_expect,
        check_fails=s.check_fails,
        rounds=s.rounds + jnp.sum(mask.astype(jnp.int32)))


def _remote_turn_b(wl, s: PCState, rmask) -> PCState:
    """Masked multi-consumer drain: every masked consumer drains its own
    partition's fullest producer in ONE set of scoped ops.  Lanes whose
    precondition fails no-op (vmapped stragglers idle harmlessly)."""
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    n = cfg.n_agents
    do = jnp.asarray(rmask, bool) & _can_remote(wl, s)
    victim = _victims(wl, s)
    lockv = victim * cfg.stride
    start = s.consumed[victim]
    end = s.produced[victim]

    st = s.store
    st, old = O.acquire(wl.proto, pc, st, do, lockv, 0, 1, scope=O.REMOTE)
    st, cnt = O.load(pc, st, do, lockv + 1)

    def rd(carry, j):
        st, seen = carry
        st, v = O.load(pc, st, do, lockv + 2 + j)
        seen = seen + jnp.where(do & (j >= start) & (j < end), v, 0)
        return (st, seen), None

    (st, seen), _ = lax.scan(rd, (st, jnp.zeros((n,), jnp.int32)),
                             jnp.arange(cfg.max_items, dtype=jnp.int32))
    st = O.release(wl.proto, pc, st, do, lockv, 0, scope=O.REMOTE)

    m = end - start
    # Σ_{j=start..end-1} item_val(victim, j), closed form, per lane
    expect = m * ((victim + 1) * 131 + 1) + 7 * (start + end - 1) * m // 2
    fails = jnp.where(do, (cnt != end).astype(jnp.int32)
                      + (old != 0).astype(jnp.int32), 0)
    return PCState(
        store=st,
        produced=s.produced,
        consumed=s.consumed.at[jnp.where(do, victim, n)].set(end,
                                                             mode="drop"),
        quota=s.quota,
        credit=jnp.where(do, jnp.int32(cfg.warmup), s.credit),
        sum_seen=s.sum_seen + jnp.sum(jnp.where(do, seen, 0)),
        sum_expect=s.sum_expect + jnp.sum(jnp.where(do, expect, 0)),
        check_fails=s.check_fails + jnp.sum(fails),
        rounds=s.rounds + jnp.sum(do.astype(jnp.int32)))


def _remote_turn(wl, s: PCState, wg) -> PCState:
    """Serializing reference turn — literally the one-hot batched turn."""
    return _remote_turn_b(wl, s, harness.one_hot(wl.cfg.n_agents, wg))


def build_workload(cfg: Config, proto: P.Protocol) -> harness.Workload:
    if not 1 <= cfg.n_consumers < cfg.n_agents:
        raise ValueError(f"n_consumers must be in [1, n_agents); got "
                         f"{cfg.n_consumers} of {cfg.n_agents} agents")
    return harness.Workload(
        name="producer_consumer", cfg=cfg, proto=proto, has_remote=True,
        can_local=_can_local, can_remote=_can_remote,
        local_turn=_local_turn, remote_turn=_remote_turn,
        remote_bound=_remote_bound, live=_live,
        remote_turn_b=_remote_turn_b, remote_addr=_remote_addr,
        retire=_retire, admit=_admit)


def init_state(wl, seed) -> PCState:
    """Pure-jnp init (vmappable over `seed`): per-producer quotas are
    seed-jittered so replicas exercise different imbalance."""
    cfg = wl.cfg
    lanes = _lanes(cfg)
    seed = jnp.asarray(seed, jnp.int32)
    spread = cfg.max_items - cfg.min_items + 1
    quota = cfg.min_items + jnp.mod(seed * 40503 + lanes * 1000003,
                                    jnp.int32(spread))
    quota = jnp.where(lanes < cfg.n_consumers, 0, quota).astype(jnp.int32)
    n = cfg.n_agents
    return PCState(
        store=P.make_store(cfg.proto_cfg()),
        produced=jnp.zeros((n,), jnp.int32),
        consumed=jnp.zeros((n,), jnp.int32),
        quota=quota,
        credit=jnp.full((n,), cfg.warmup, jnp.int32),
        sum_seen=jnp.int32(0), sum_expect=jnp.int32(0),
        check_fails=jnp.int32(0), rounds=jnp.int32(0))


def self_check(wl, final: PCState) -> dict:
    """Consistency audit: in-run failures + drained-L2 lost-update scan."""
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    fails = int(final.check_fails)
    fails += int(final.sum_seen != final.sum_expect)
    done = bool(np.all(np.asarray(final.consumed) >=
                       np.asarray(final.quota)))
    st = harness.drain_all(pc, final.store)
    l2 = np.asarray(st.l2).reshape(-1)
    quota = np.asarray(final.quota)
    for i in range(cfg.n_consumers, cfg.n_agents):
        base = i * cfg.stride
        if l2[base + 1] != quota[i]:
            fails += 1
        want = np.asarray(_item_val(i, np.arange(quota[i])))
        fails += int(np.sum(l2[base + 2:base + 2 + quota[i]] != want))
    return {"ok": fails == 0 and done, "check_fails": fails,
            "done": done, "events": int(final.rounds)}


def build(scenario: str, n_agents: int, seed: int = 0, *,
          proto: P.Protocol = None, **kw) -> harness.Bench:
    return harness.make_bench(Config(n_agents=n_agents, **kw),
                              build_workload, init_state, self_check,
                              scenario, seed, proto)
