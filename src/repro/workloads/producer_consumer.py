"""Producer/consumer drains — single remote consumer, many local producers.

Asymmetry shape: every producer appends items to its *own* ring region
with local-scope synchronization (the overwhelmingly common op); one
consumer agent periodically performs a *remote-scope* drain of the
fullest producer's region (the rare op).  This is the inverse of
work-stealing's thief distribution — one hot remote agent instead of
many occasional ones — and matches the one-sided access pattern of
RDMA-style asymmetric mutual exclusion (arXiv:2208.09540).

Spec (DESIGN.md §7):
  * local turns: producer i appends item `produced[i]` inside its own
    lock's critical section; the consumer burns a scratch turn (its own
    region) while its drain credit is positive.  All local turns touch
    pairwise-disjoint regions → they commute.
  * remote turn: the consumer (agent 0) remote-acquires the victim's
    lock, reads the count word and every fresh item THROUGH the store,
    and releases.  Victim choice (largest produced-consumed gap) and the
    consumed bookkeeping use host-invisible ground truth only, so the
    schedule is identical under a buggy protocol — the bug surfaces in
    the checked values, not as divergence.
  * fence: the consumer's next drain is at least `credit · scratch_cost`
    cycles away (each scratch turn charges exactly that); producers
    never go remote (bound = BIG).
  * self-check: count word must equal the victim's true produced count
    at the drain's serial position; Σ item values read must equal the
    bookkept Σ expected; post-run, the drained L2 image must hold every
    item (lost-update audit).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import protocol as P
from repro.core import tables
from repro.core.costmodel import CostParams
from repro.workloads import harness

VMAPPABLE = True


@dataclasses.dataclass(frozen=True)
class Config:
    n_agents: int = 8
    max_items: int = 8          # static per-producer quota bound
    min_items: int = 4
    warmup: int = 3             # consumer scratch turns between drains
    scratch_cost: float = 20.0  # compute cycles charged per local turn
    fifo_cap: int = 16
    lr_tbl: tables.TableGeometry = tables.LR_GEOMETRY
    pa_tbl: tables.TableGeometry = tables.PA_GEOMETRY
    params: CostParams = dataclasses.field(default_factory=CostParams)

    @property
    def stride(self) -> int:
        return (2 + self.max_items + 15) // 16 * 16

    @property
    def n_words(self) -> int:
        return self.n_agents * self.stride

    def proto_cfg(self) -> P.ProtoConfig:
        return P.ProtoConfig(n_caches=self.n_agents, n_words=self.n_words,
                             fifo_cap=self.fifo_cap, lr_tbl=self.lr_tbl,
                             pa_tbl=self.pa_tbl, params=self.params)


class PCState(NamedTuple):
    store: P.Store
    produced: jnp.ndarray    # [n] i32 bookkeeping: items appended per producer
    consumed: jnp.ndarray    # [n] i32 bookkeeping: items drained per producer
    quota: jnp.ndarray       # [n] i32 per-producer target (0 for agent 0)
    credit: jnp.ndarray      # [] i32 consumer scratch turns before next drain
    sum_seen: jnp.ndarray    # [] i32 Σ item values read THROUGH the store
    sum_expect: jnp.ndarray  # [] i32 Σ expected values of drained items
    check_fails: jnp.ndarray # [] i32 in-run consistency violations
    rounds: jnp.ndarray      # [] i32


def _item_val(agent, j):
    """Deterministic item payload — what the self-check replays."""
    return (jnp.asarray(agent, jnp.int32) + 1) * 131 \
        + 7 * jnp.asarray(j, jnp.int32) + 1


def _max_events(cfg: Config) -> int:
    return (cfg.warmup + 3) * cfg.n_agents * cfg.max_items + 4 * cfg.n_agents


def _lanes(cfg: Config):
    return jnp.arange(cfg.n_agents, dtype=jnp.int32)


def _can_local(wl, s: PCState):
    lanes = _lanes(wl.cfg)
    live = jnp.any(s.consumed < s.quota)
    return jnp.where(lanes == 0, (s.credit > 0) & live, s.produced < s.quota)


def _can_remote(wl, s: PCState):
    lanes = _lanes(wl.cfg)
    live = jnp.any(s.consumed < s.quota)
    return (lanes == 0) & (s.credit == 0) & live


def _remote_bound(wl, s: PCState):
    lanes = _lanes(wl.cfg)
    return jnp.where(lanes == 0,
                     s.credit.astype(jnp.float32) * wl.cfg.scratch_cost,
                     harness.BIG)


def _live(wl, s: PCState):
    return jnp.any(s.consumed < s.quota) & (s.rounds < _max_events(wl.cfg))


def _local_turn(wl, s: PCState, mask) -> PCState:
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    lanes = _lanes(cfg)
    is0 = lanes == 0
    prod = mask & ~is0
    cons = mask & is0
    locks = lanes * cfg.stride

    st = s.store
    # producers: append inside own critical section (local-scope sync)
    st, _ = wl.proto.owner_acquire_b(pc, st, prod, locks, 0, 1)
    slot = jnp.clip(s.produced, 0, cfg.max_items - 1)
    st, _ = P.b_store_word(pc, st, prod, locks + 2 + slot,
                           _item_val(lanes, s.produced))
    st, _ = P.b_store_word(pc, st, prod, locks + 1, s.produced + 1)
    st = wl.proto.owner_release_b(pc, st, prod, locks, 0)
    # consumer: scratch write in its own region (write-combining dirt)
    st, _ = P.b_store_word(pc, st, cons,
                           locks + 2 + s.credit % jnp.int32(cfg.max_items),
                           jnp.broadcast_to(s.credit, (cfg.n_agents,)))
    st = harness.charge(st, mask, cfg.scratch_cost)

    return PCState(
        store=st,
        produced=s.produced + prod.astype(jnp.int32),
        consumed=s.consumed,
        quota=s.quota,
        credit=s.credit - cons[0].astype(jnp.int32),
        sum_seen=s.sum_seen, sum_expect=s.sum_expect,
        check_fails=s.check_fails,
        rounds=s.rounds + jnp.sum(mask.astype(jnp.int32)))


def _remote_turn(wl, s: PCState, wg) -> PCState:
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    do = _can_remote(wl, s)[wg]   # the scheduler's own predicate, in sync

    def drain(s: PCState) -> PCState:
        gap = (s.produced - s.consumed).at[0].set(-1)  # never self-drain
        victim = jnp.argmax(gap).astype(jnp.int32)
        lockv = victim * cfg.stride
        start = s.consumed[victim]
        end = s.produced[victim]

        st = s.store
        st, old = wl.proto.thief_acquire(pc, st, 0, lockv, 0, 1)
        st, cnt = P.load(pc, st, 0, lockv + 1)
        seen = jnp.int32(0)

        def rd(carry, j):
            st, seen = carry
            st, v = P.load(pc, st, 0, lockv + 2 + j)
            seen = seen + jnp.where((j >= start) & (j < end), v, 0)
            return (st, seen), None

        (st, seen), _ = lax.scan(rd, (st, seen),
                                 jnp.arange(cfg.max_items, dtype=jnp.int32))
        st = wl.proto.thief_release(pc, st, 0, lockv, 0)

        m = end - start
        # Σ_{j=start..end-1} item_val(victim, j), closed form
        expect = m * ((victim + 1) * 131 + 1) + 7 * (start + end - 1) * m // 2
        fails = (cnt != end).astype(jnp.int32) + (old != 0).astype(jnp.int32)
        return PCState(
            store=st,
            produced=s.produced,
            consumed=s.consumed.at[victim].set(end),
            quota=s.quota,
            credit=jnp.int32(cfg.warmup),
            sum_seen=s.sum_seen + seen,
            sum_expect=s.sum_expect + expect,
            check_fails=s.check_fails + fails,
            rounds=s.rounds + 1)

    def idle(s: PCState) -> PCState:
        return s._replace(rounds=s.rounds + 1)

    return lax.cond(do, drain, idle, s)


def build_workload(cfg: Config, proto: P.Protocol) -> harness.Workload:
    return harness.Workload(
        name="producer_consumer", cfg=cfg, proto=proto, has_remote=True,
        can_local=_can_local, can_remote=_can_remote,
        local_turn=_local_turn, remote_turn=_remote_turn,
        remote_bound=_remote_bound, live=_live)


def init_state(wl, seed) -> PCState:
    """Pure-jnp init (vmappable over `seed`): per-producer quotas are
    seed-jittered so replicas exercise different imbalance."""
    cfg = wl.cfg
    lanes = _lanes(cfg)
    seed = jnp.asarray(seed, jnp.int32)
    spread = cfg.max_items - cfg.min_items + 1
    quota = cfg.min_items + jnp.mod(seed * 40503 + lanes * 1000003,
                                    jnp.int32(spread))
    quota = jnp.where(lanes == 0, 0, quota).astype(jnp.int32)
    n = cfg.n_agents
    return PCState(
        store=P.make_store(cfg.proto_cfg()),
        produced=jnp.zeros((n,), jnp.int32),
        consumed=jnp.zeros((n,), jnp.int32),
        quota=quota,
        credit=jnp.int32(cfg.warmup),
        sum_seen=jnp.int32(0), sum_expect=jnp.int32(0),
        check_fails=jnp.int32(0), rounds=jnp.int32(0))


def self_check(wl, final: PCState) -> dict:
    """Consistency audit: in-run failures + drained-L2 lost-update scan."""
    cfg = wl.cfg
    pc = cfg.proto_cfg()
    fails = int(final.check_fails)
    fails += int(final.sum_seen != final.sum_expect)
    done = bool(np.all(np.asarray(final.consumed) >=
                       np.asarray(final.quota)))
    st = harness.drain_all(pc, final.store)
    l2 = np.asarray(st.l2).reshape(-1)
    quota = np.asarray(final.quota)
    for i in range(1, cfg.n_agents):
        base = i * cfg.stride
        if l2[base + 1] != quota[i]:
            fails += 1
        want = np.asarray(_item_val(i, np.arange(quota[i])))
        fails += int(np.sum(l2[base + 2:base + 2 + quota[i]] != want))
    return {"ok": fails == 0 and done, "check_fails": fails,
            "done": done, "events": int(final.rounds)}


def build(scenario: str, n_agents: int, seed: int = 0, *,
          proto: P.Protocol = None, **kw) -> harness.Bench:
    return harness.make_bench(Config(n_agents=n_agents, **kw),
                              build_workload, init_state, self_check,
                              scenario, seed, proto)
