"""Protocol × workload × size sweep — the paper's Fig. 5/6 comparisons
generalized across every registered asymmetric-sharing workload.

Grid: workload × scenario (baseline / scope_only / rsp / srsp) × n_agents,
batched engine.  Emits BENCH_workloads.json (schema: benchmarks/SCHEMA.md,
version 2) with **compile time reported separately from steady state**:

  * compile_s           first-call wall time (jit trace+compile + 1st run)
  * steady_s_per_run    mean wall of subsequent full runs (fresh states,
                        same shapes → jit cache hits)
  * steady_s_per_replica  the vmapped path packs `--seeds` seed-varied
                        replicas into ONE compiled `run_batched_many` call
                        per (workload, protocol, size) cell — compilation
                        count stays at one per cell no matter how many
                        replicas run (the "as few compilations as
                        possible" contract).  Per-replica cost divides by
                        the batch width.

Protocol comparisons use *modeled makespan* (max per-agent cycles — the
paper's metric), not wall clock; wall clock measures the simulator
engine, makespan measures the protocol.  `scope_only` is expected to
FAIL self-checks on workloads with remote turns (local-scope remote sync
is the paper's staleness demo) — `check_ok: false` in those rows is the
workload subsystem working, not a bug.

Also runs two worksteal steady-state A/Bs in subprocesses (the toggles
are read at import, so a fresh process per arm is the only honest
measurement):

  * donation_ab — REPRO_NO_DONATE (buffer donation through the jit
    boundary, the first ROADMAP n_wgs=256 candidate);
  * pack_ab     — REPRO_NO_PACK (packed uint32 word-bitmask metadata
    planes vs the boolean layout, DESIGN.md §8 — the fix for the
    in-loop-scatter bound the donation A/B exonerated).

Schema v3 additions (benchmarks/SCHEMA.md): per-run `table_geometry`
(LR/PA sets×ways) and top-level `packed_metadata`, plus the `pack_ab`
section.

Schema v5 additions (elastic alive-set PR, DESIGN.md §10): per-run
churn columns (`churn_events`, `churn_rate`, `recovered`,
`lost_updates`) plus ONE churned robustness cell — the worksteal srsp
bench under a pinned die-holding-lock crash on the batched elastic
engine, which must complete via the lease-expiry recovery drain with
zero lost updates among survivors.  Every cell also runs under a
per-cell hang watchdog (runtime/fault.py StepTimer + Heartbeat +
interrupt timer; `REPRO_NO_WATCHDOG=1` disables).

Schema v6 additions (observability PR, DESIGN.md §11): per-run
`latency_p50/p95/p99` / `latency_turns` (conservative upper-edge
percentiles of the per-turn modeled-latency histogram) and
`trace_events`/`trace_dropped` (event-ring occupancy) — populated only
under `REPRO_TRACE=1`; tracing charges no cycles, so every other column
is bitwise unchanged by the flag.  One traced srsp cell is additionally
exported as Perfetto-loadable Chrome-trace JSON (`--trace-out`), and
top-level `stragglers` lists watchdog-flagged slow cells.

Schema v7 additions (fused megakernel PR, DESIGN.md §12): grid rows for
`engine="fused"` (the one-kernel batched trip) on the srsp scenario by
default (`--fused-scenarios`), a per-run + top-level `kernel_mode`
column ("pallas" / "ref" / "interpret" — chosen once per process,
`kernels/common.py`) so an interpret-mode timing can never masquerade as
a measurement, and the `fuse_ab` section: the vmapped kv_directory srsp
cell run engine="fused" vs engine="batched" in one process at
`--fuse-sizes` (the vmapped path is where the fusion win lives — the
batched engine's cond branches all execute under vmap, the fused engine
runs ONE masked local turn).  The A/B asserts identical modeled
makespans (the §12 equivalence argument in vivo) and reports
`steady_speedup_fused`.

Schema v8 additions (traffic subsystem PR, DESIGN.md §13): per-run
`offered_load` / `completed` / `zipf_s` / `burstiness` columns (None on
self-driven workloads) and `latency_source` — trace-driven rows
(kv_serving) fill the latency percentiles from their per-REQUEST
completion-latency histogram (state-resident, populated with tracing
compiled off; pooled across replicas), self-driven rows keep the §11
per-turn trace source.  Plus the `serving` section: kv_serving at
`--serving-sizes` under Zipf skew `--serving-zipf` (s ∈ {0.9, 1.2}),
srsp batched vs srsp fused (asserted: same makespan, completed count and
latency histogram — the same generated trace replayed bitwise across
engines) vs rsp batched, reporting `srsp_vs_rsp_makespan` and
`srsp_vs_rsp_p99` per skew (auto-gated by benchmarks/compare.py), and
ONE serving-scale cell: >= 1e6 simulated requests replayed through the
vmapped fused path per scenario (srsp/rsp/baseline), self-checks green.
A second churned robustness cell runs kv_serving under the pinned
crash_holding_lock + CRASH-event recovery (tests/test_kv_serving.py pins
the same numbers).

Schema v4 additions (scope-parametric ISA PR, DESIGN.md §9): per-run
`api` ("scoped" — every workload issues ops through `repro.core.ops`)
and `remote_batch` (whether the workload×protocol pair can co-schedule
address-disjoint remote turns), plus the `remote_batch_ab` section: the
multi-consumer producer/consumer cell run with the batched remote twins
vs with `faults.serialize_remote` (scalar serialized remote turns), in
one process — the capability is carried by the Protocol object, not an
env flag.  The A/B asserts identical modeled makespans (the §9
commutation rule holding in vivo) and reports the wall-clock effect.

Usage:
  PYTHONPATH=src python -m repro.workloads.sweep \
      [--workloads all] [--scenarios baseline scope_only rsp srsp]
      [--sizes 16 64] [--seeds 2] [--iters 2] [--no-donation]
      [--donation-sizes 64 256] [--no-pack-ab] [--pack-sizes 64 256]
      [--no-remote-batch-ab] [--no-churn] [--fused-scenarios srsp]
      [--no-fuse-ab] [--fuse-sizes 64 256] [--out BENCH_workloads.json]
"""
from __future__ import annotations

import _thread
import argparse
import json
import os
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np

# allow `python src/repro/workloads/sweep.py` without PYTHONPATH
sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

import jax
import jax.numpy as jnp

from repro import workloads
from repro.core import protocol as P
from repro.kernels import common as kcommon
from repro.obs import export as obs_export, metrics, trace as T
from repro.runtime import fault as rtfault
from repro.traffic.samplers import TrafficConfig
from repro.workloads import faults, harness

SCHEMA_VERSION = 8
DEFAULT_SCENARIOS = ["baseline", "scope_only", "rsp", "srsp"]

# per-cell hang budget for the watchdog (seconds)
WATCHDOG_S = float(os.environ.get("REPRO_WATCHDOG_S", "600"))


class CellWatchdog:
    """Per-cell hang watchdog — runtime/fault.py wired into the sweep.

    A `Heartbeat` file records sweep liveness for outside watchers, a
    `StepTimer` flags straggler cells (z-score over the cell history),
    and a `threading.Timer` interrupts the main thread if a single cell
    exceeds WATCHDOG_S — a wedged `while_loop` (e.g. a crash injection
    without its recovery drain) fails the sweep loudly instead of
    hanging CI.  `REPRO_NO_WATCHDOG=1` disables everything (debuggers,
    profilers, very slow boxes)."""

    def __init__(self, heartbeat_path: str = None):
        if heartbeat_path is None:
            # per-process path in the tmpdir: a fixed repo-local filename
            # collides across concurrent sweeps (and a crashed run's
            # stale file would impersonate the next one)
            heartbeat_path = os.path.join(
                tempfile.gettempdir(), f"sweep_heartbeat.{os.getpid()}")
        self.enabled = os.environ.get("REPRO_NO_WATCHDOG", "0") != "1"
        self.timer = rtfault.StepTimer(window=50, z_thresh=3.0)
        self.hb = rtfault.Heartbeat(heartbeat_path, interval=5.0)
        self.cells = 0
        self.label = "?"
        self.stragglers = []   # [{cell, wall_s}] — surfaced in the bench
        self._t = None

    def start(self, label: str):
        self.label = label
        if not self.enabled:
            return
        self.timer.start()
        self.hb.beat(self.cells)
        self._t = threading.Timer(WATCHDOG_S, self._fire)
        self._t.daemon = True
        self._t.start()

    def _fire(self):
        print(f"WATCHDOG: cell {self.label} exceeded {WATCHDOG_S:.0f}s "
              f"budget — interrupting the sweep", file=sys.stderr, flush=True)
        _thread.interrupt_main()

    def stop(self):
        self.cells += 1
        if not self.enabled:
            return
        self._t.cancel()
        dt, straggler = self.timer.stop()
        if straggler:
            self.stragglers.append({"cell": self.label,
                                    "wall_s": round(dt, 2)})
            print(f"watchdog: straggler cell {self.label} ({dt:.1f}s, "
                  f"z>{self.timer.z_thresh})", flush=True)

    def close(self):
        """End of sweep: cancel any pending interrupt, remove the
        heartbeat file (stale liveness files alias later runs)."""
        if self._t is not None:
            self._t.cancel()
        self.hb.stop()


def _lane0(tree):
    return jax.tree.map(lambda x: x[0], tree)


def _geometry(wl) -> dict:
    """Schema-v3 table-geometry column: the LR/PA sets×ways this cell ran
    with (derived from the workload's protocol config, not literals)."""
    pc = wl.cfg.proto_cfg()
    return {"lr": str(pc.lr_tbl), "pa": str(pc.pa_tbl)}


def _api_cols(wl) -> dict:
    """Schema-v4 columns: the op surface (always the scoped ISA since the
    cutover) and whether this workload×protocol pair co-schedules
    address-disjoint remote turns (DESIGN.md §9)."""
    return {"api": "scoped",
            "remote_batch": bool(wl.remote_turn_b is not None
                                 and wl.remote_addr is not None
                                 and wl.proto.remote_batchable)}


def _churn_cols(churn_events=0, makespan=0.0, recovered=0.0,
                lost_updates=0) -> dict:
    """Schema-v5 columns (DESIGN.md §10): churn_events fired during the
    run, churn_rate per 1k modeled cycles, agents reclaimed by recovery
    drains, and updates lost among survivors (must be 0 when recovery is
    on).  Zero-churn grid cells carry literal zeros."""
    rate = 1e3 * churn_events / makespan if makespan else 0.0
    return {"churn_events": int(churn_events),
            "churn_rate": round(rate, 5),
            "recovered": float(recovered),
            "lost_updates": int(lost_updates)}


def _latency_cols(store) -> dict:
    """Schema-v6 columns (DESIGN.md §11): conservative upper-edge
    p50/p95/p99 of the per-turn modeled-latency histogram plus trace
    ring occupancy — all None/0 unless the sweep runs under
    REPRO_TRACE=1 (tracing charges nothing, so every other column is
    bitwise unchanged by the flag)."""
    return T.summary(store)


def _traffic_cols(wl, checks) -> dict:
    """Schema-v8 columns (DESIGN.md §13): offered vs completed request
    totals (summed across replicas) and the traffic shape that generated
    them.  Self-driven workloads carry None — the column distinguishes
    'no traffic model' from 'zero requests'."""
    if not checks or "offered" not in checks[0]:
        return {"offered_load": None, "completed": None,
                "zipf_s": None, "burstiness": None}
    tc = wl.cfg.traffic
    return {"offered_load": int(sum(c["offered"] for c in checks)),
            "completed": int(sum(c["completed"] for c in checks)),
            "zipf_s": tc.zipf_s, "burstiness": tc.burstiness}


def _request_latency(rec, checks) -> None:
    """Trace-driven rows (schema v8) report latency percentiles of the
    per-REQUEST completion histogram — state-resident, so populated even
    with tracing compiled off — pooled across replicas.  Self-driven
    rows keep the §11 per-turn trace source (when REPRO_TRACE=1)."""
    if checks and "latency_hist" in checks[0]:
        pooled = np.sum([np.asarray(c["latency_hist"], np.int64)
                         for c in checks], axis=0)
        lat = metrics.summarize(pooled)
        rec.update({"latency_p50": lat["p50"], "latency_p95": lat["p95"],
                    "latency_p99": lat["p99"],
                    "latency_turns": lat["count"],
                    "latency_source": "requests"})
    else:
        rec["latency_source"] = "turns" if rec.get("trace_events") \
            else None


def measure_vmapped(mod, name, scenario, n_agents, n_seeds, iters,
                    engine="batched", build_kw=None):
    """One compiled `runner_many(engine)` call per cell; replicas ride
    the vmap.  engine="fused" times the one-kernel batched trip
    (schema v7, DESIGN.md §12).  `build_kw` overrides workload-config
    fields (the v8 serving section's traffic shapes)."""
    run_many = harness.runner_many(engine)
    bench = mod.build(scenario, n_agents, seed=0, **(build_kw or {}))
    wl = bench.wl

    def states(base):
        seeds = jnp.arange(base, base + n_seeds, dtype=jnp.int32)
        return jax.vmap(lambda s: mod.init_state(wl, s))(seeds)

    t0 = time.perf_counter()
    out = run_many(wl, states(0))
    jax.block_until_ready(out.store.counters.cycles)
    compile_s = time.perf_counter() - t0

    times = []
    for it in range(max(1, iters)):
        st = states((it + 1) * n_seeds)
        t0 = time.perf_counter()
        out = run_many(wl, st)
        jax.block_until_ready(out.store.counters.cycles)
        times.append(time.perf_counter() - t0)

    # self-check EVERY replica (cheap, host-side) — seed-jittered lanes
    # can exercise failure modes lane 0 doesn't
    checks = [mod.self_check(wl, jax.tree.map(lambda x: x[k], out))
              for k in range(n_seeds)]
    lane = _lane0(out)
    counters = harness.counters_dict(lane.store)
    steady = float(np.mean(times))
    rec = {
        "workload": name, "scenario": scenario, "n_agents": n_agents,
        "engine": engine, "kernel_mode": kcommon.kernel_mode(),
        "vmapped": True, "n_replicas": n_seeds,
        "table_geometry": _geometry(wl), **_api_cols(wl),
        "iters_timed": iters,
        "compile_s": round(compile_s, 4),
        "steady_s_per_run": round(steady, 5),
        "steady_s_per_replica": round(steady / n_seeds, 5),
        **_churn_cols(), **_latency_cols(lane.store),
        **_traffic_cols(wl, checks),
        "events": int(lane.rounds),
        "check_ok": all(c["ok"] for c in checks),
        "check_fails": int(sum(c["check_fails"] for c in checks)),
        "makespan": counters["makespan"],
        "counters": counters,
        "_trace_store": lane.store,
    }
    _request_latency(rec, checks)
    return rec


def measure_host_init(mod, name, scenario, n_agents, iters,
                      engine="batched"):
    """Non-vmappable workloads (worksteal: host-side enqueue): fresh
    state per run, shared jit cache across runs."""
    run = harness.runner(engine)
    bench = mod.build(scenario, n_agents, seed=0)
    t0 = time.perf_counter()
    out = run(bench.wl, bench.state, *bench.ops)
    jax.block_until_ready(out.store.counters.cycles)
    compile_s = time.perf_counter() - t0

    times = []
    for it in range(max(1, iters)):
        b = mod.build(scenario, n_agents, seed=it + 1)
        t0 = time.perf_counter()
        out = run(b.wl, b.state, *b.ops)
        jax.block_until_ready(out.store.counters.cycles)
        times.append(time.perf_counter() - t0)
        check = b.check(out)

    counters = harness.counters_dict(out.store)
    rec = {
        "workload": name, "scenario": scenario, "n_agents": n_agents,
        "engine": engine, "kernel_mode": kcommon.kernel_mode(),
        "vmapped": False, "n_replicas": 1,
        "table_geometry": _geometry(bench.wl), **_api_cols(bench.wl),
        "iters_timed": iters,
        "compile_s": round(compile_s, 4),
        "steady_s_per_run": round(float(np.mean(times)), 5),
        "steady_s_per_replica": round(float(np.mean(times)), 5),
        **_churn_cols(), **_latency_cols(out.store),
        **_traffic_cols(bench.wl, [check]),
        "events": int(out.rounds),
        "check_ok": bool(check["ok"]),
        "check_fails": int(check["check_fails"]),
        "makespan": counters["makespan"],
        "counters": counters,
        "_trace_store": out.store,
    }
    _request_latency(rec, [check])
    return rec


# ---------------- subprocess A/Bs (donation / packed metadata) -------------
# Both toggles are read once at import of their module, so each arm runs in
# a fresh subprocess with the env var set — the only honest measurement.

_WS_SNIPPET = r"""
import json, sys, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.worksteal import WorkStealSim, WSConfig
from repro.data.graphs import collab_like

n_wgs, iters = int(sys.argv[1]), int(sys.argv[2])
n_chunks = max(2 * n_wgs, 64)
ws = WSConfig(n_wgs=n_wgs, chunk_cap=32, n_chunks_max=n_chunks)
g = collab_like(n=32 * (n_chunks // 2), m=4, seed=2)
sim = WorkStealSim(ws, "srsp", "batched")
store = sim.make_store()
last_inv = jnp.zeros((ws.n_wgs,), jnp.float32)
frontier = np.arange(g.n, dtype=np.int32)
t0 = time.perf_counter()
store, last_inv, e, _ = sim.run_iteration(store, frontier, g.degrees, last_inv)
jax.block_until_ready(store.counters.cycles)
compile_s = time.perf_counter() - t0
times = []
for _ in range(max(1, iters)):
    t0 = time.perf_counter()
    store, last_inv, e, _ = sim.run_iteration(store, frontier, g.degrees,
                                              last_inv)
    jax.block_until_ready(store.counters.cycles)
    times.append(time.perf_counter() - t0)
print(json.dumps({"compile_s": round(compile_s, 4),
                  "steady_s_per_iter": round(float(np.mean(times)), 5),
                  "proc_errors": int(e)}))
"""


def _measure_ws_subprocess(n_wgs, iters, env_overrides: dict, label: str):
    """One worksteal srsp steady-state arm in a fresh subprocess."""
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    env.update(env_overrides)
    out = subprocess.run(
        [sys.executable, "-c", _WS_SNIPPET, str(n_wgs), str(iters)],
        capture_output=True, text=True, env=env)
    if out.returncode != 0:
        print(out.stderr[-2000:], file=sys.stderr)
        raise RuntimeError(f"{label} subprocess failed: n_wgs={n_wgs} "
                           f"env={env_overrides}")
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    rec.update({"n_wgs": n_wgs, "workload": "worksteal",
                "scenario": "srsp", "engine": "batched"})
    return rec


def measure_donation(n_wgs, iters, donate: bool):
    rec = _measure_ws_subprocess(
        n_wgs, iters, {"REPRO_NO_DONATE": "0" if donate else "1"},
        "donation")
    rec["donate"] = donate
    return rec


def measure_pack(n_wgs, iters, packed: bool):
    rec = _measure_ws_subprocess(
        n_wgs, iters, {"REPRO_NO_PACK": "0" if packed else "1"}, "pack")
    rec["packed"] = packed
    return rec


# ---------------- churned robustness cell (schema v5, DESIGN.md §10) -------

def measure_churned_cell(iters):
    """The worksteal srsp bench with a pinned die-holding-lock crash
    (faults.crash_holding_lock, victim 0 at clock 5; CRASH churn event at
    clock 400 — tests/test_churn.py pins the same numbers) run on the
    batched ELASTIC engine.  srsp must COMPLETE despite the crash: the
    lease-expiry recovery drain reclaims the dead owner's dirty words and
    force-releases its leased lock, after which thieves drain its queue.
    `recovered` counts reclaimed agents, `lost_updates` check failures
    among survivors (must be 0 with recovery on)."""
    mod = workloads.get("worksteal")
    victim, at, evt = 0, 5.0, 400.0
    proto = faults.crash_holding_lock(P.get_protocol("srsp"), victim, at)

    def one():
        b = mod.build("srsp", 4, seed=3, proto=proto, n_chunks_max=12)
        eb = harness.make_elastic(b, events=[(evt, victim, "crash")])
        fin = harness.run_batched_elastic(eb.wl, eb.state, *eb.ops)
        jax.block_until_ready(fin.s.store.counters.cycles)
        return b.wl, fin, eb.check(fin)

    t0 = time.perf_counter()
    wl, fin, check = one()
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        wl, fin, check = one()
        times.append(time.perf_counter() - t0)

    counters = harness.counters_dict(fin.s.store)
    recovered = float(np.sum(np.asarray(fin.s.store.counters.recoveries)))
    rec = {
        "workload": "worksteal", "scenario": "srsp", "n_agents": 4,
        "engine": "batched_elastic", "kernel_mode": kcommon.kernel_mode(),
        "vmapped": False, "n_replicas": 1,
        "table_geometry": _geometry(wl), **_api_cols(wl),
        "iters_timed": iters,
        "compile_s": round(compile_s, 4),
        "steady_s_per_run": round(float(np.mean(times)), 5),
        "steady_s_per_replica": round(float(np.mean(times)), 5),
        **_churn_cols(churn_events=1, makespan=counters["makespan"],
                      recovered=recovered,
                      lost_updates=check["check_fails"]),
        **_latency_cols(fin.s.store), **_traffic_cols(wl, []),
        "events": int(check["events"]),
        "check_ok": bool(check["ok"]),
        "check_fails": int(check["check_fails"]),
        "makespan": counters["makespan"],
        "counters": counters,
        "_trace_store": fin.s.store,
    }
    _request_latency(rec, [])
    return rec


# -------- churned serving cell (schema v8, DESIGN.md §13 + §10) ------------

def measure_churned_serving(iters):
    """kv_serving under the pinned die-holding-lock crash
    (crash_holding_lock victim 0 at clock 30; CRASH churn event at clock
    180 — tests/test_kv_serving.py pins the same numbers) on the batched
    elastic engine, single page per agent so the wedged victim strands
    exactly one lock.  The recovery drain must write back the victim's
    committed pages and force-release the stranded lock, after which the
    survivors' Zipf-skewed lookups of the dead shard's hot page complete
    — self-check clean, no lost pages, no stale reads."""
    mod = workloads.get("kv_serving")
    victim, at, evt = 0, 30.0, 180.0
    proto = faults.crash_holding_lock(P.get_protocol("srsp"), victim, at)

    def one():
        b = mod.build("srsp", 4, seed=3, proto=proto, pages_per_agent=1)
        eb = harness.make_elastic(b, events=[(evt, victim, "crash")])
        fin = harness.run_batched_elastic(eb.wl, eb.state, *eb.ops)
        jax.block_until_ready(fin.s.store.counters.cycles)
        return b.wl, fin, eb.check(fin)

    t0 = time.perf_counter()
    wl, fin, check = one()
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        wl, fin, check = one()
        times.append(time.perf_counter() - t0)

    counters = harness.counters_dict(fin.s.store)
    recovered = float(np.sum(np.asarray(fin.s.store.counters.recoveries)))
    rec = {
        "workload": "kv_serving", "scenario": "srsp", "n_agents": 4,
        "engine": "batched_elastic", "kernel_mode": kcommon.kernel_mode(),
        "vmapped": False, "n_replicas": 1,
        "table_geometry": _geometry(wl), **_api_cols(wl),
        "iters_timed": iters,
        "compile_s": round(compile_s, 4),
        "steady_s_per_run": round(float(np.mean(times)), 5),
        "steady_s_per_replica": round(float(np.mean(times)), 5),
        **_churn_cols(churn_events=1, makespan=counters["makespan"],
                      recovered=recovered,
                      lost_updates=check["check_fails"]),
        **_latency_cols(fin.s.store), **_traffic_cols(wl, [check]),
        "events": int(check["events"]),
        "check_ok": bool(check["ok"]),
        "check_fails": int(check["check_fails"]),
        "makespan": counters["makespan"],
        "counters": counters,
        "_trace_store": fin.s.store,
    }
    _request_latency(rec, [check])
    return rec


# ---------------- remote-batch A/B (schema v4, DESIGN.md §9) ---------------

def measure_remote_batch(n_agents, n_seeds, iters, batched: bool):
    """producer_consumer_mc srsp cell with the batched remote twins vs
    with `faults.serialize_remote` (remote turns serialized).  In-process:
    the capability rides on the Protocol object, so the two arms compile
    as distinct static keys.  Modeled makespans must be IDENTICAL (the §9
    commutation rule); wall clock measures the co-scheduling win."""
    mod = workloads.get("producer_consumer_mc")
    proto = None if batched else faults.serialize_remote(
        P.get_protocol("srsp"))
    bench = mod.build("srsp", n_agents, seed=0, proto=proto)
    wl = bench.wl

    def states(base):
        seeds = jnp.arange(base, base + n_seeds, dtype=jnp.int32)
        return jax.vmap(lambda s: mod.init_state(wl, s))(seeds)

    t0 = time.perf_counter()
    out = harness.run_batched_many(wl, states(0))
    jax.block_until_ready(out.store.counters.cycles)
    compile_s = time.perf_counter() - t0
    times = []
    for it in range(max(1, iters)):
        st = states((it + 1) * n_seeds)
        t0 = time.perf_counter()
        out = harness.run_batched_many(wl, st)
        jax.block_until_ready(out.store.counters.cycles)
        times.append(time.perf_counter() - t0)
    checks = [mod.self_check(wl, jax.tree.map(lambda x: x[k], out))
              for k in range(n_seeds)]
    lane = _lane0(out)
    return {
        "workload": "producer_consumer_mc", "scenario": "srsp",
        "n_agents": n_agents, "engine": "batched", "n_replicas": n_seeds,
        "remote_batch": batched,
        "compile_s": round(compile_s, 4),
        "steady_s_per_run": round(float(np.mean(times)), 5),
        "events": int(lane.rounds),
        "check_ok": all(c["ok"] for c in checks),
        "makespan": float(harness.counters_dict(lane.store)["makespan"]),
    }


# ---------------- fused-engine A/B (schema v7, DESIGN.md §12) --------------

def measure_fuse(n_agents, n_seeds, iters, engine):
    """kv_directory srsp vmapped cell, engine="fused" vs "batched" in one
    process (engine selection is a function lookup, not an import-time
    flag, so both arms compile as distinct jit keys honestly).  The
    vmapped path is where the fusion win lives: under vmap the batched
    engine's cond branches ALL execute (two local turns + both remote
    forms per trip), the fused engine runs ONE masked local turn.
    Modeled makespans must be IDENTICAL (§12 equivalence in vivo)."""
    mod = workloads.get("kv_directory")
    run_many = harness.runner_many(engine)
    bench = mod.build("srsp", n_agents, seed=0)
    wl = bench.wl

    def states(base):
        seeds = jnp.arange(base, base + n_seeds, dtype=jnp.int32)
        return jax.vmap(lambda s: mod.init_state(wl, s))(seeds)

    t0 = time.perf_counter()
    out = run_many(wl, states(0))
    jax.block_until_ready(out.store.counters.cycles)
    compile_s = time.perf_counter() - t0
    times = []
    for it in range(max(1, iters)):
        st = states((it + 1) * n_seeds)
        t0 = time.perf_counter()
        out = run_many(wl, st)
        jax.block_until_ready(out.store.counters.cycles)
        times.append(time.perf_counter() - t0)
    checks = [mod.self_check(wl, jax.tree.map(lambda x: x[k], out))
              for k in range(n_seeds)]
    lane = _lane0(out)
    return {
        "workload": "kv_directory", "scenario": "srsp",
        "n_agents": n_agents, "engine": engine,
        "kernel_mode": kcommon.kernel_mode(), "n_replicas": n_seeds,
        "compile_s": round(compile_s, 4),
        "steady_s_per_run": round(float(np.mean(times)), 5),
        "events": int(lane.rounds),
        "check_ok": all(c["ok"] for c in checks),
        "makespan": float(harness.counters_dict(lane.store)["makespan"]),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workloads", nargs="+", default=["all"])
    ap.add_argument("--scenarios", nargs="+", default=DEFAULT_SCENARIOS)
    ap.add_argument("--sizes", nargs="+", type=int, default=[16, 64])
    ap.add_argument("--seeds", type=int, default=2,
                    help="replicas per vmapped cell (one compilation)")
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--no-donation", action="store_true",
                    help="skip the buffer-donation A/B")
    ap.add_argument("--donation-sizes", nargs="+", type=int,
                    default=[64, 256])
    ap.add_argument("--donation-iters", type=int, default=2)
    ap.add_argument("--no-pack-ab", action="store_true",
                    help="skip the packed-vs-boolean metadata A/B")
    ap.add_argument("--pack-sizes", nargs="+", type=int, default=[64, 256])
    ap.add_argument("--pack-iters", type=int, default=2)
    ap.add_argument("--no-remote-batch-ab", action="store_true",
                    help="skip the batched-vs-serialized remote-turn A/B")
    ap.add_argument("--remote-batch-sizes", nargs="+", type=int,
                    default=[16, 64])
    ap.add_argument("--fused-scenarios", nargs="+", default=["srsp"],
                    help="scenarios that also get engine=fused grid rows "
                         "(schema v7; 'none' disables)")
    ap.add_argument("--no-fuse-ab", action="store_true",
                    help="skip the fused-vs-batched engine A/B")
    ap.add_argument("--fuse-sizes", nargs="+", type=int, default=[64, 256])
    ap.add_argument("--no-churn", action="store_true",
                    help="skip the churned crash-recovery cell")
    ap.add_argument("--no-serving", action="store_true",
                    help="skip the trace-driven serving sections "
                         "(schema v8: skewed-traffic comparison + scale "
                         "cell; the grid kv_serving rows still run)")
    ap.add_argument("--serving-sizes", nargs="+", type=int, default=[64])
    ap.add_argument("--serving-zipf", nargs="+", type=float,
                    default=[0.9, 1.2],
                    help="Zipf skew exponents for the serving comparison")
    ap.add_argument("--serving-requests", type=int, default=256,
                    help="requests per agent in each serving cell")
    ap.add_argument("--serving-seeds", type=int, default=2,
                    help="replicas per serving comparison cell")
    ap.add_argument("--serving-scale-replicas", type=int, default=64,
                    help="replicas for the >=1e6-request scale cell "
                         "(0 disables; 64 x n=64 x 256 req = 1,048,576 "
                         "simulated requests per scenario)")
    ap.add_argument("--trace-out", default="TRACE_sweep.json",
                    help="Perfetto trace JSON for one traced srsp cell "
                         "(only written under REPRO_TRACE=1)")
    ap.add_argument("--out", default="BENCH_workloads.json")
    args = ap.parse_args(argv)

    names = workloads.available() if args.workloads == ["all"] \
        else args.workloads
    wd = CellWatchdog()

    runs = []
    trace_store, trace_label = None, None

    def harvest(rec, label):
        """Pop the stashed final store; keep the first traced srsp cell
        for the Perfetto export."""
        nonlocal trace_store, trace_label
        store = rec.pop("_trace_store", None)
        if (store is not None and trace_store is None
                and rec["scenario"] == "srsp" and rec["trace_events"]):
            trace_store, trace_label = store, label

    fused_scens = [] if args.fused_scenarios == ["none"] \
        else args.fused_scenarios
    for name in names:
        mod = workloads.get(name)
        for n_agents in args.sizes:
            for scen in args.scenarios:
                engines = ["batched"] + (["fused"] if scen in fused_scens
                                         else [])
                for engine in engines:
                    label = f"{name}/{scen}/n={n_agents}/{engine}"
                    t0 = time.perf_counter()
                    wd.start(label)
                    with jax.profiler.TraceAnnotation(f"cell:{label}"):
                        if mod.VMAPPABLE:
                            rec = measure_vmapped(mod, name, scen, n_agents,
                                                  args.seeds, args.iters,
                                                  engine)
                        else:
                            rec = measure_host_init(mod, name, scen,
                                                    n_agents, args.iters,
                                                    engine)
                    wd.stop()
                    harvest(rec, label)
                    rec["bench_wall_s"] = round(time.perf_counter() - t0, 2)
                    runs.append(rec)
                    print(f"{label}: "
                          f"compile={rec['compile_s']:.2f}s "
                          f"steady={rec['steady_s_per_run'] * 1e3:.1f}ms "
                          f"makespan={rec['makespan']:.0f} "
                          f"check_ok={rec['check_ok']}", flush=True)
            jax.clear_caches()   # per-size programs are large on CPU

    if not args.no_churn:
        label = "worksteal/srsp+crash/churned"
        wd.start(label)
        with jax.profiler.TraceAnnotation(f"cell:{label}"):
            rec = measure_churned_cell(args.iters)
        wd.stop()
        harvest(rec, label)
        runs.append(rec)
        print(f"churned worksteal/srsp (crash victim 0): "
              f"check_ok={rec['check_ok']} recovered={rec['recovered']:.0f} "
              f"lost_updates={rec['lost_updates']} "
              f"churn_rate={rec['churn_rate']}/kcycle", flush=True)
        jax.clear_caches()

    if not args.no_churn and "kv_serving" in names:
        label = "kv_serving/srsp+crash/churned"
        wd.start(label)
        with jax.profiler.TraceAnnotation(f"cell:{label}"):
            rec = measure_churned_serving(args.iters)
        wd.stop()
        harvest(rec, label)
        runs.append(rec)
        print(f"churned kv_serving/srsp (crash victim 0): "
              f"check_ok={rec['check_ok']} recovered={rec['recovered']:.0f} "
              f"lost_updates={rec['lost_updates']} "
              f"completed={rec['completed']}/{rec['offered_load']}",
              flush=True)
        jax.clear_caches()

    # ---- trace-driven serving sections (schema v8, DESIGN.md §13) ----
    serving = []
    serving_comparisons = {}
    if not args.no_serving:
        kv_mod = workloads.get("kv_serving")
        for n in args.serving_sizes:
            for s in args.serving_zipf:
                tc = TrafficConfig(requests_per_agent=args.serving_requests,
                                   zipf_s=s, gap_mean=8.0, burstiness=4.0,
                                   remote_frac=0.03)
                cell = {}
                for scen, engine in (("srsp", "batched"), ("srsp", "fused"),
                                     ("rsp", "batched")):
                    label = (f"serving/kv_serving/{scen}/zipf={s}"
                             f"/n={n}/{engine}")
                    t0 = time.perf_counter()
                    wd.start(label)
                    with jax.profiler.TraceAnnotation(f"cell:{label}"):
                        rec = measure_vmapped(
                            kv_mod, "kv_serving", scen, n,
                            args.serving_seeds, args.iters, engine,
                            build_kw={"traffic": tc})
                    wd.stop()
                    rec.pop("_trace_store", None)
                    rec["bench_wall_s"] = round(time.perf_counter() - t0, 2)
                    serving.append(rec)
                    cell[(scen, engine)] = rec
                    print(f"{label}: "
                          f"steady={rec['steady_s_per_run']:.2f}s "
                          f"completed={rec['completed']}"
                          f"/{rec['offered_load']} "
                          f"p99={rec['latency_p99']} "
                          f"check_ok={rec['check_ok']}", flush=True)
                jax.clear_caches()
                sb = cell[("srsp", "batched")]
                sf = cell[("srsp", "fused")]
                rb = cell[("rsp", "batched")]
                # same (seed, config) trace replayed through both engines:
                # the fused trip is bitwise the batched schedule, so every
                # modeled column must agree exactly
                assert sf["makespan"] == sb["makespan"], (sf, sb)
                assert sf["completed"] == sb["completed"], (sf, sb)
                assert sf["latency_p99"] == sb["latency_p99"], (sf, sb)
                serving_comparisons[f"serving/kv_serving/zipf={s}/n={n}"] = {
                    "srsp_vs_rsp_makespan": round(
                        rb["makespan"] / sb["makespan"], 3),
                    "srsp_vs_rsp_p99": round(
                        rb["latency_p99"] / max(sb["latency_p99"], 1.0), 3),
                    "engines_bitwise": True,
                    "offered_load": sb["offered_load"],
                    "completed": sb["completed"]}

        if args.serving_scale_replicas > 0:
            tc = TrafficConfig(requests_per_agent=256, zipf_s=1.2,
                               gap_mean=8.0, burstiness=4.0,
                               remote_frac=0.01)
            n = 64
            scale = {}
            for scen in ("srsp", "rsp", "baseline"):
                label = f"serving-scale/kv_serving/{scen}/n={n}/fused"
                t0 = time.perf_counter()
                wd.start(label)
                with jax.profiler.TraceAnnotation(f"cell:{label}"):
                    rec = measure_vmapped(
                        kv_mod, "kv_serving", scen, n,
                        args.serving_scale_replicas, 1, "fused",
                        build_kw={"traffic": tc})
                wd.stop()
                rec.pop("_trace_store", None)
                rec["bench_wall_s"] = round(time.perf_counter() - t0, 2)
                serving.append(rec)
                scale[scen] = rec
                wall = rec["steady_s_per_run"]
                print(f"{label}: {rec['completed']}/{rec['offered_load']} "
                      f"requests in {wall:.1f}s "
                      f"({rec['completed'] / max(wall, 1e-9):,.0f} req/s) "
                      f"p99={rec['latency_p99']} "
                      f"check_ok={rec['check_ok']}", flush=True)
                jax.clear_caches()
            assert all(r["check_ok"] for r in scale.values()), scale
            serving_comparisons[f"serving_scale/kv_serving/zipf=1.2/n={n}"] \
                = {"offered_load": scale["srsp"]["offered_load"],
                   "completed": scale["srsp"]["completed"],
                   "all_checks_ok": True,
                   "srsp_vs_rsp_makespan": round(
                       scale["rsp"]["makespan"]
                       / scale["srsp"]["makespan"], 3),
                   "srsp_vs_rsp_p99": round(
                       scale["rsp"]["latency_p99"]
                       / max(scale["srsp"]["latency_p99"], 1.0), 3),
                   "srsp_vs_baseline_makespan": round(
                       scale["baseline"]["makespan"]
                       / scale["srsp"]["makespan"], 3)}

    trace_file = None
    if trace_store is not None and args.trace_out:
        obs_export.write_trace(args.trace_out, trace_store,
                               label=trace_label,
                               stragglers=wd.stragglers)
        trace_file = args.trace_out
        print(f"wrote {args.trace_out} (traced cell: {trace_label})")

    def find(name, scen, n, engine="batched"):
        for r in runs:
            if (r["workload"], r["scenario"], r["n_agents"],
                    r["engine"]) == (name, scen, n, engine) \
                    and not r["churn_events"]:
                return r
        return None

    # paper-style protocol comparisons on modeled makespan + L2 traffic
    comparisons = {}
    comparisons.update(serving_comparisons)
    churned = [r for r in runs if r["churn_events"]]
    for r in churned:
        comparisons[f"churn/{r['workload']}/n={r['n_agents']}"] = {
            "completes_under_crash": bool(r["check_ok"]),
            "recovered": r["recovered"],
            "lost_updates": r["lost_updates"]}
    for name in names:
        for n in args.sizes:
            srsp = find(name, "srsp", n)
            rsp = find(name, "rsp", n)
            base = find(name, "baseline", n)
            if not srsp:
                continue
            entry = {}
            if rsp:
                entry["srsp_vs_rsp_makespan"] = round(
                    rsp["makespan"] / srsp["makespan"], 3)
                entry["srsp_vs_rsp_l2"] = round(
                    rsp["counters"]["l2_accesses"]
                    / max(srsp["counters"]["l2_accesses"], 1.0), 3)
            if base:
                entry["srsp_vs_baseline_makespan"] = round(
                    base["makespan"] / srsp["makespan"], 3)
            comparisons[f"{name}/n={n}"] = entry

    # fused grid rows: the fused engine is bitwise the batched schedule
    # (tests/test_engine_equivalence.py) — a diverging makespan here is a
    # broken build, not a data point
    for name in names:
        for n in args.sizes:
            for scen in fused_scens:
                fus = find(name, scen, n, "fused")
                bat = find(name, scen, n, "batched")
                if not fus or not bat:
                    continue
                assert fus["makespan"] == bat["makespan"], (fus, bat)
                comparisons[f"fused/{name}/{scen}/n={n}"] = {
                    "makespan_equal": True,
                    "steady_speedup_fused": round(
                        bat["steady_s_per_run"]
                        / fus["steady_s_per_run"], 3)}

    donation = []
    if not args.no_donation:
        for n_wgs in args.donation_sizes:
            for donate in (True, False):
                rec = measure_donation(n_wgs, args.donation_iters, donate)
                donation.append(rec)
                print(f"donation n_wgs={n_wgs} donate={donate}: "
                      f"steady={rec['steady_s_per_iter']:.3f}s/iter "
                      f"compile={rec['compile_s']:.1f}s", flush=True)
        for n_wgs in args.donation_sizes:
            on = next(r for r in donation
                      if r["n_wgs"] == n_wgs and r["donate"])
            off = next(r for r in donation
                       if r["n_wgs"] == n_wgs and not r["donate"])
            comparisons[f"donation/n_wgs={n_wgs}"] = {
                "steady_speedup_donate": round(
                    off["steady_s_per_iter"] / on["steady_s_per_iter"], 3)}

    pack_ab = []
    if not args.no_pack_ab:
        for n_wgs in args.pack_sizes:
            for packed in (True, False):
                rec = measure_pack(n_wgs, args.pack_iters, packed)
                pack_ab.append(rec)
                print(f"pack n_wgs={n_wgs} packed={packed}: "
                      f"steady={rec['steady_s_per_iter']:.3f}s/iter "
                      f"compile={rec['compile_s']:.1f}s", flush=True)
        for n_wgs in args.pack_sizes:
            on = next(r for r in pack_ab
                      if r["n_wgs"] == n_wgs and r["packed"])
            off = next(r for r in pack_ab
                       if r["n_wgs"] == n_wgs and not r["packed"])
            comparisons[f"packed/n_wgs={n_wgs}"] = {
                "steady_speedup_packed": round(
                    off["steady_s_per_iter"] / on["steady_s_per_iter"], 3)}

    remote_batch_ab = []
    if not args.no_remote_batch_ab:
        for n in args.remote_batch_sizes:
            for batched in (True, False):
                rec = measure_remote_batch(n, args.seeds, args.iters,
                                           batched)
                remote_batch_ab.append(rec)
                print(f"remote_batch n={n} batched={batched}: "
                      f"steady={rec['steady_s_per_run'] * 1e3:.1f}ms "
                      f"makespan={rec['makespan']:.0f} "
                      f"check_ok={rec['check_ok']}", flush=True)
            jax.clear_caches()
        for n in args.remote_batch_sizes:
            on = next(r for r in remote_batch_ab
                      if r["n_agents"] == n and r["remote_batch"])
            off = next(r for r in remote_batch_ab
                       if r["n_agents"] == n and not r["remote_batch"])
            # §9 commutation rule holding in vivo: co-scheduled remote
            # turns must not change the modeled schedule at all
            assert on["makespan"] == off["makespan"], (on, off)
            comparisons[f"remote_batch/n={n}"] = {
                "makespan_equal": True,
                "steady_speedup_batched": round(
                    off["steady_s_per_run"] / on["steady_s_per_run"], 3)}

    fuse_ab = []
    if not args.no_fuse_ab:
        for n in args.fuse_sizes:
            for engine in ("fused", "batched"):
                rec = measure_fuse(n, args.seeds, args.iters, engine)
                fuse_ab.append(rec)
                print(f"fuse n={n} engine={engine}: "
                      f"steady={rec['steady_s_per_run'] * 1e3:.1f}ms "
                      f"makespan={rec['makespan']:.0f} "
                      f"check_ok={rec['check_ok']}", flush=True)
            jax.clear_caches()
        for n in args.fuse_sizes:
            on = next(r for r in fuse_ab
                      if r["n_agents"] == n and r["engine"] == "fused")
            off = next(r for r in fuse_ab
                       if r["n_agents"] == n and r["engine"] == "batched")
            # §12 equivalence in vivo: the fused trip must not change the
            # modeled schedule at all
            assert on["makespan"] == off["makespan"], (on, off)
            comparisons[f"fuse/n={n}"] = {
                "makespan_equal": True,
                "steady_speedup_fused": round(
                    off["steady_s_per_run"] / on["steady_s_per_run"], 3)}

    doc = {
        "bench": "workloads_sweep",
        "schema_version": SCHEMA_VERSION,
        "metric_note": "compile_s is jit trace+compile+first run, reported "
                       "separately from steady_s_per_run (fresh states, "
                       "cached program). Protocol comparisons use modeled "
                       "makespan (max per-agent cycles), the paper's "
                       "metric; wall clock measures the engine. scope_only "
                       "check_ok=false on remote-turn workloads is the "
                       "expected staleness demo. Every workload issues "
                       "ops through the scoped ISA (api=scoped, DESIGN.md "
                       "SS9). srsp>rsp holds on every workload and widens "
                       "with n_agents (the paper's claim). With the "
                       "set-associative aging PA-TBL and the "
                       "filtered-probe charging rule (DESIGN.md SS8), "
                       "srsp>=baseline on kv_directory, reader_lock and "
                       "worksteal. producer_consumer stays below baseline "
                       "by construction: its always-hot drainers pay "
                       "srsp's probe round on their critical path in BOTH "
                       "scenarios. The multi-consumer variant "
                       "(producer_consumer_mc: partitioned victims, "
                       "drains co-scheduled via the batched remote twins) "
                       "parallelizes the remote work itself — makespan "
                       "goes ~flat in n (4072 at n=64 vs 31680 "
                       "single-consumer) and the srsp/baseline ratio "
                       "improves 0.87->0.94 at n=64 — but does NOT reach "
                       "parity: co-scheduling removes the drain "
                       "serialization, not the per-drain probe overhead, "
                       "which remains additive on each drainer (ROADMAP "
                       "follow-up outcome, recorded either way). "
                       "remote_batch_ab asserts batched and serialized "
                       "remote turns produce IDENTICAL makespans (the SS9 "
                       "commutation rule in vivo); its wall-clock "
                       "steady_speedup_batched is CPU-simulator noise "
                       "prone (fewer while-trips vs per-trip dedup "
                       "overhead; ~1.8x at n=16, ~1.0x at n=64 here). "
                       "Schema v5 (DESIGN.md SS10): churn_events/"
                       "churn_rate/recovered/lost_updates columns; the "
                       "engine=batched_elastic cell injects a "
                       "die-holding-lock crash and srsp completes via the "
                       "lease-expiry recovery drain with lost_updates=0 "
                       "among survivors; zero-churn cells are bitwise "
                       "identical to the plain engines (tests/"
                       "test_churn.py). Schema v6 (DESIGN.md SS11): "
                       "latency_p50/p95/p99/latency_turns are "
                       "conservative upper-edge percentiles of the "
                       "per-turn modeled-latency histogram and "
                       "trace_events/trace_dropped the event-ring "
                       "occupancy, populated only under REPRO_TRACE=1 "
                       "(tracing charges nothing: every other column is "
                       "bitwise unchanged by the flag); stragglers lists "
                       "watchdog-flagged slow cells and one traced srsp "
                       "cell is exported as Perfetto JSON (--trace-out). "
                       "Schema v7 (DESIGN.md SS12): engine=fused grid rows "
                       "time the one-kernel batched trip (bitwise the "
                       "batched schedule — asserted on every fused cell "
                       "and in fuse_ab); kernel_mode records the "
                       "once-per-process kernel dispatch (pallas/ref/"
                       "interpret) so an interpret-mode number can never "
                       "masquerade as a measurement. The fusion win is "
                       "structural on the vmapped path (batched executes "
                       "both cond branches under vmap, fused runs ONE "
                       "masked local turn). The unvmapped CPU rows "
                       "(worksteal) trade the other way: lax.cond "
                       "branches are lazy there, so the batched engine "
                       "skips the n x n remote-dedup math whenever a "
                       "local batch exists while the fused plan computes "
                       "it every trip — those rows can dip below 1.0x "
                       "(0.80x at n=64); the vmapped rows and fuse_ab "
                       "carry the perf claim. Schema v8 (DESIGN.md SS13): "
                       "offered_load/completed/zipf_s/burstiness columns "
                       "on trace-driven cells (null elsewhere) and "
                       "latency_source marks whether latency_p50/p95/p99 "
                       "summarize per-request completion latency "
                       "(='requests', always on for trace-driven cells: "
                       "completion clock minus arrival clock from the "
                       "replayed trace) or the per-turn REPRO_TRACE "
                       "histogram (='turns'). The serving section replays "
                       "the SAME (seed, config) Zipf+bursty trace through "
                       "the batched and fused engines (asserted equal "
                       "makespan/completed/p99) and reports "
                       "srsp_vs_rsp_makespan and srsp_vs_rsp_p99 under "
                       "skew s in {0.9, 1.2}; the scale cell pushes "
                       ">=1e6 simulated requests per scenario through the "
                       "vmapped fused path with self-checks green on "
                       "srsp/rsp/baseline. The churned kv_serving cell "
                       "crashes a shard owner holding its page lock "
                       "mid-trace: the lease recovery drain must "
                       "force-release it and survivors finish with no "
                       "lost pages and no stale reads.",
        "backend": jax.default_backend(),
        "donate_buffers": harness.DONATE,
        "packed_metadata": P.PACKED,
        "kernel_mode": kcommon.kernel_mode(),
        "fuse_enabled": harness.FUSE,
        "trace": {"enabled": T.TRACE, "capacity": T.default_cap(),
                  "file": trace_file, "cell": trace_label},
        "stragglers": wd.stragglers,
        "config": {"workloads": names, "scenarios": args.scenarios,
                   "sizes": args.sizes, "seeds": args.seeds,
                   "iters": args.iters,
                   "serving": None if args.no_serving else {
                       "sizes": args.serving_sizes,
                       "zipf": args.serving_zipf,
                       "requests_per_agent": args.serving_requests,
                       "seeds": args.serving_seeds,
                       "scale_replicas": args.serving_scale_replicas,
                       "gap_mean": 8.0, "burstiness": 4.0}},
        "runs": runs,
        "serving": serving,
        "donation_ab": donation,
        "pack_ab": pack_ab,
        "remote_batch_ab": remote_batch_ab,
        "fuse_ab": fuse_ab,
        "comparisons": comparisons,
    }
    wd.close()
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")
    for k, v in comparisons.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
