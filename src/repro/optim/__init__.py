from repro.optim.optimizers import make_optimizer, cosine_schedule  # noqa: F401
