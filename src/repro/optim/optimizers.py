"""Sharded optimizers: AdamW (f32 moments) and Adafactor (factored second
moments — the memory-efficient choice for the 123B/671B train cells).

Functional API (no optax dependency): make_optimizer returns
(init_fn, update_fn); optimizer state inherits the parameter sharding, so
ZeRO-style optimizer sharding falls out of the FSDP param specs for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int = 100,
                    total: int = 10000, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.minimum(warm, 1.0) * cos
    return lr


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), n


# ----------------------------------------------------------------- AdamW


def make_adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
               clip_norm=1.0):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"m": m, "v": v, "step": step}, gnorm

    return init, update


# -------------------------------------------------------------- Adafactor


def make_adafactor(lr_fn, eps=1e-30, clip_threshold=1.0, decay=0.8,
                   weight_decay=0.0, clip_norm=1.0):
    """Factored second moments for params with >= 2 dims (row/col stats);
    O(rows+cols) optimizer memory instead of O(rows*cols)."""

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(one, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        beta = 1.0 - (step.astype(jnp.float32)) ** -decay

        def upd(g, f, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                vr = beta * f["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * f["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                     eps))
                cfac = jax.lax.rsqrt(vc)
                u = gf * rfac[..., None] * cfac[..., None, :]
                nf = {"vr": vr, "vc": vc}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(v)
                nf = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), nf

        leaves = {"f": state["f"]}
        out = jax.tree.map(upd, grads, leaves["f"], params,
                           is_leaf=lambda x: isinstance(x, dict)
                           and ("vr" in x or "v" in x))
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        nf = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return updates, {"f": nf, "step": step}, gnorm

    return init, update


def make_optimizer(name: str, lr: float = 3e-4, warmup: int = 100,
                   total_steps: int = 10000, **kw):
    lr_fn = cosine_schedule(lr, warmup, total_steps)
    if name == "adamw":
        return make_adamw(lr_fn, **kw)
    if name == "adafactor":
        return make_adafactor(lr_fn, **kw)
    raise ValueError(f"unknown optimizer {name!r}")


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
