"""Benchmark aggregator: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows per the harness contract.

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time


def section(title):
    print(f"\n# === {title} ===", flush=True)


def main():
    quick = "--quick" in sys.argv
    t_all = time.time()

    section("kernel micro-benchmarks (name,us_per_call,derived)")
    from benchmarks import kernel_bench
    kernel_bench.main()

    section("paper Fig4/5/6 + scaling (work-stealing scenarios)")
    from benchmarks import paper_figs
    paper_figs.main(8 if quick else 16)

    section("sRSP cross-pod selective delta sync (framework layer)")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    subprocess.run([sys.executable, "-m", "benchmarks.delta_sync_bench"],
                   env=env, check=True)

    section("roofline table (from dry-run artifacts)")
    if os.path.isdir("artifacts/dryrun"):
        from benchmarks import roofline
        rows = roofline.load()
        if rows:
            print(roofline.table(rows))
    section("analytic roofline (primary §Roofline artifact)")
    from benchmarks.analytic_roofline import main as arl
    arl()

    print(f"\n[benchmarks done in {time.time()-t_all:.0f}s]")


if __name__ == "__main__":
    main()
