"""Framework-layer benchmark: sRSP-style selective cross-pod delta sync vs
full all-reduce, on banks with asymmetric update sparsity (MoE expert banks,
embedding rows).  Reports bytes moved + wall time on a simulated pod axis.

Run inside a process with forced host devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m benchmarks.delta_sync_bench
(benchmarks/run.py spawns it that way.)"""
from __future__ import annotations

import json
import os
import sys
import time


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.distributed.hier_sync import bank_init, make_pod_sync

    n_pods = min(4, len(jax.devices()))
    mesh = Mesh(np.array(jax.devices()[:n_pods]).reshape(n_pods), ("pod",))
    rng = np.random.default_rng(0)
    rows = []
    for (nb, bs, frac_dirty, label) in [
            (256, 2048, 0.03, "moe_expert_bank"),     # ~granite expert FFN
            (1024, 1024, 0.02, "embedding_rows"),
            (256, 2048, 0.50, "dense_layer(worst)"),
    ]:
        base = rng.normal(size=(nb, bs)).astype(np.float32)
        banks = np.broadcast_to(base, (n_pods, nb, bs)).copy()
        for pod in range(n_pods):
            k = max(1, int(nb * frac_dirty))
            idx = rng.choice(nb, size=k, replace=False)
            banks[pod, idx] += 0.01 * rng.normal(size=(k, bs))
        max_dirty = max(8, int(nb * frac_dirty * n_pods * 2))
        st = jax.vmap(bank_init)(jnp.asarray(
            np.broadcast_to(base, (n_pods, nb, bs)).copy()))
        sh = lambda x: jax.device_put(x, NamedSharding(
            mesh, P(*(("pod",) + (None,) * (x.ndim - 1)))))
        banks_j = sh(jnp.asarray(banks))
        st = jax.tree.map(sh, st)
        out = {"bank": label, "n_blocks": nb, "block": bs,
               "dirty_frac": frac_dirty}
        for mode, selective in (("srsp_selective", True), ("full_ar", False)):
            sync = make_pod_sync(mesh, nb, bs, max_dirty=max_dirty,
                                 selective=selective)
            nbk, nst = sync(banks_j, st)          # compile+run
            jax.block_until_ready(nbk)
            t0 = time.perf_counter()
            for _ in range(5):
                nbk, nst2 = sync(banks_j, st)
            jax.block_until_ready(nbk)
            dt = (time.perf_counter() - t0) / 5
            moved = float(np.asarray(nst.bytes_selective)[0])
            out[f"{mode}_bytes"] = moved
            out[f"{mode}_us"] = dt * 1e6
        out["bytes_ratio"] = out["srsp_selective_bytes"] / out["full_ar_bytes"]
        rows.append(out)
        print(f"  {label:22s} dirty={frac_dirty:4.0%} "
              f"selective={out['srsp_selective_bytes']/2**20:8.2f}MiB "
              f"full={out['full_ar_bytes']/2**20:8.2f}MiB "
              f"ratio={out['bytes_ratio']:.3f}", flush=True)
    os.makedirs("artifacts/paper", exist_ok=True)
    json.dump(rows, open("artifacts/paper/delta_sync.json", "w"), indent=1)


if __name__ == "__main__":
    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
    main()
