"""Paper reproduction benchmarks (Fig 4, 5, 6 + the scalability claim).

Fig 4 — speedup of {scope_only, steal_only, rsp, srsp} over Baseline for
        PageRank / SSSP / MIS on DIMACS-like synthetic graphs.
Fig 5 — L2 data transactions per scenario (bandwidth proxy).
Fig 6 — sync overhead of sRSP relative to RSP.
Scaling — sRSP vs RSP remote-op cost as the CU count grows (8..64): the
        paper's core claim is that RSP's flush-all cost scales with CUs
        while sRSP's selective flush does not.
"""
from __future__ import annotations

import json
import math
import os
import time

from repro.core.worksteal import WSConfig, run_app, reference_solution
from repro.data.graphs import collab_like, road_like, router_like

SCENARIOS = ["baseline", "scope_only", "steal_only", "rsp", "srsp"]

# (app, graph builder, iters) — graph scales chosen for the CPU simulator;
# character matches the paper's inputs (EXPERIMENTS.md §Repro notes)
APPS = [
    ("pagerank", lambda: collab_like(n=2048, m=6, seed=0), 3),
    ("sssp", lambda: road_like(n=2025, seed=2), 8),
    ("mis", lambda: router_like(n=2048, seed=1), 6),
]


def run_all(n_wgs: int = 16, out_dir: str = "artifacts/paper"):
    os.makedirs(out_dir, exist_ok=True)
    results = {}
    for app, build, iters in APPS:
        g = build()
        n_chunks = min((g.n + 31) // 32, 256)
        ws = WSConfig(n_wgs=n_wgs, chunk_cap=32, n_chunks_max=n_chunks)
        ref = reference_solution(app, g, max_iters=iters)
        for scen in SCENARIOS:
            t0 = time.perf_counter()
            r = run_app(app, g, scen, ws, max_iters=iters)
            ok = r.proc_errors == 0
            if app == "pagerank":
                import numpy as np
                ok = ok and np.allclose(r.solution, ref, rtol=1e-4)
            results[(app, scen)] = {
                "makespan": r.makespan, "ok": bool(ok),
                "wall_s": round(time.perf_counter() - t0, 1),
                **{k: r.counters[k] for k in
                   ("l2_accesses", "wb_blocks", "inv_full", "steals",
                    "remote_syncs", "promotions", "probes")}}
            print(f"  {app:9s} {scen:11s} makespan={r.makespan:12.0f} "
                  f"l2={r.counters['l2_accesses']:9.0f} ok={ok}", flush=True)
    json.dump({f"{a}|{s}": v for (a, s), v in results.items()},
              open(os.path.join(out_dir, f"figs_{n_wgs}wg.json"), "w"),
              indent=1)
    return results


def fig4_rows(results):
    rows = []
    geo = {s: 1.0 for s in SCENARIOS}
    n = 0
    for app, _, _ in APPS:
        base = results[(app, "baseline")]["makespan"]
        n += 1
        for s in SCENARIOS:
            sp = base / results[(app, s)]["makespan"]
            geo[s] *= sp
            rows.append((app, s, sp))
    for s in SCENARIOS:
        rows.append(("geomean", s, geo[s] ** (1.0 / n)))
    return rows


def fig5_rows(results):
    rows = []
    for app, _, _ in APPS:
        base = max(results[(app, "baseline")]["l2_accesses"], 1.0)
        for s in SCENARIOS:
            rows.append((app, s, results[(app, s)]["l2_accesses"] / base))
    return rows


def fig6_rows(results):
    """Sync overhead of sRSP relative to RSP: extra cycles spent on remote
    sync machinery (makespan - scope_only work floor)."""
    rows = []
    for app, _, _ in APPS:
        floor = results[(app, "srsp")]["makespan"]
        over_rsp = results[(app, "rsp")]["makespan"]
        rows.append((app, "srsp_vs_rsp",
                     results[(app, "srsp")]["makespan"] / over_rsp))
        del floor
    return rows


def scaling_sweep(out_dir: str = "artifacts/paper"):
    """Remote-op cost vs CU count — the scalability claim (§1, §7)."""
    rows = []
    g = collab_like(n=1024, m=5, seed=0)
    for n_wgs in (8, 16, 32, 64):
        ws = WSConfig(n_wgs=n_wgs, chunk_cap=32, n_chunks_max=64)
        out = {}
        for scen in ("rsp", "srsp"):
            r = run_app("pagerank", g, scen, ws, max_iters=2)
            rem = max(r.counters["remote_syncs"], 1.0)
            out[scen] = {
                "makespan": r.makespan,
                "inv_per_remote": r.counters["inv_full"] / rem,
                "wb_per_remote": r.counters["wb_blocks"] / rem,
                "l2": r.counters["l2_accesses"],
            }
        rows.append({"n_wgs": n_wgs, **{f"{s}_{k}": v
                                        for s, d in out.items()
                                        for k, v in d.items()}})
        print(f"  scaling n_wgs={n_wgs:3d} "
              f"rsp_inv/remote={out['rsp']['inv_per_remote']:6.1f} "
              f"srsp_inv/remote={out['srsp']['inv_per_remote']:6.2f}",
              flush=True)
    json.dump(rows, open(os.path.join(out_dir, "scaling.json"), "w"),
              indent=1)
    return rows


def main(n_wgs: int = 16):
    print(f"[paper figs] scenarios x apps at {n_wgs} work-groups")
    results = run_all(n_wgs=n_wgs)
    print("\nFig4 speedup over Baseline:")
    for app, s, sp in fig4_rows(results):
        print(f"  {app:9s} {s:11s} {sp:5.2f}x")
    print("\nFig5 relative L2 accesses:")
    for app, s, rel in fig5_rows(results):
        print(f"  {app:9s} {s:11s} {rel:6.3f}")
    print("\nScaling sweep (RSP vs sRSP invalidations per remote op):")
    scaling_sweep()


if __name__ == "__main__":
    import sys
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 16)
