"""Bench regression gate: diff two BENCH_workloads.json files.

    python benchmarks/compare.py BASE.json NEW.json [--makespan-tol 0.02]
        [--p99-tol 0.10] [--ratio-tol 0.05] [--advisory]

Matches runs by (workload, scenario, n_agents, engine) and flags:

  * modeled-makespan growth beyond --makespan-tol (the protocol metric
    is deterministic per seed/config, so the default tolerance is
    tight — any real growth is a schedule change, not noise);
  * latency_p99 growth beyond --p99-tol when both files carry the
    schema-v6 latency columns (upper-edge buckets are quantized in
    powers of two, so the tolerance mostly absorbs one-bucket moves);
  * a check_ok that flipped true -> false (always a regression);
  * srsp_vs_* comparison ratios that dropped by more than --ratio-tol
    (srsp losing ground against rsp/baseline), and churn cells that
    stopped completing or started losing updates.

Wall-clock columns are deliberately NOT gated — they measure the host,
not the protocol.  Exit status: 0 clean, 1 regressions (unless
--advisory, which reports but exits 0 — the CI perf-diff job).  Cells
missing from NEW (or new cells without a baseline) are notes, not
failures, so grid growth doesn't break the gate.
"""
from __future__ import annotations

import argparse
import json
import sys

KEY = ("workload", "scenario", "n_agents", "engine")


def run_key(r) -> tuple:
    return tuple(r.get(k) for k in KEY)


def _fmt_key(k) -> str:
    return f"{k[0]}/{k[1]}/n={k[2]}/{k[3]}"


def compare_docs(base: dict, new: dict, *, makespan_tol: float,
                 p99_tol: float, ratio_tol: float) -> tuple:
    """-> (regressions, improvements, notes) — lists of strings."""
    regressions, improvements, notes = [], [], []
    bruns = {run_key(r): r for r in base.get("runs", [])}
    nruns = {run_key(r): r for r in new.get("runs", [])}

    for k in sorted(nruns.keys() - bruns.keys(), key=str):
        notes.append(f"new cell (no baseline): {_fmt_key(k)}")
    for k in sorted(bruns.keys() - nruns.keys(), key=str):
        notes.append(f"cell missing from new bench: {_fmt_key(k)}")

    for k in sorted(bruns.keys() & nruns.keys(), key=str):
        br, nr = bruns[k], nruns[k]
        name = _fmt_key(k)
        if br.get("check_ok") and not nr.get("check_ok"):
            regressions.append(f"{name}: check_ok true -> false")
        if br.get("makespan") and nr.get("makespan") is not None:
            ratio = nr["makespan"] / br["makespan"]
            if ratio > 1 + makespan_tol:
                regressions.append(
                    f"{name}: makespan {br['makespan']:.0f} -> "
                    f"{nr['makespan']:.0f} (+{(ratio - 1) * 100:.1f}%)")
            elif ratio < 1 - makespan_tol:
                improvements.append(
                    f"{name}: makespan {br['makespan']:.0f} -> "
                    f"{nr['makespan']:.0f} ({(ratio - 1) * 100:.1f}%)")
        bp, np_ = br.get("latency_p99"), nr.get("latency_p99")
        if bp and np_ is not None:
            ratio = np_ / bp
            if ratio > 1 + p99_tol:
                regressions.append(
                    f"{name}: latency_p99 {bp:g} -> {np_:g} "
                    f"(+{(ratio - 1) * 100:.1f}%)")
            elif ratio < 1 - p99_tol:
                improvements.append(
                    f"{name}: latency_p99 {bp:g} -> {np_:g}")

    bcmp = base.get("comparisons", {})
    ncmp = new.get("comparisons", {})
    for cname in sorted(bcmp.keys() & ncmp.keys()):
        bc, nc = bcmp[cname], ncmp[cname]
        for field, bv in sorted(bc.items()):
            nv = nc.get(field)
            if nv is None:
                continue
            if field.startswith("srsp_vs_") and isinstance(bv, (int, float)):
                if nv < bv * (1 - ratio_tol):
                    regressions.append(
                        f"comparisons[{cname}].{field}: {bv} -> {nv} "
                        f"(srsp lost ground)")
                elif nv > bv * (1 + ratio_tol):
                    improvements.append(
                        f"comparisons[{cname}].{field}: {bv} -> {nv}")
            elif field == "completes_under_crash" and bv and not nv:
                regressions.append(
                    f"comparisons[{cname}]: stopped completing under crash")
            elif field == "lost_updates" and not bv and nv:
                regressions.append(
                    f"comparisons[{cname}]: lost_updates {bv} -> {nv}")
    return regressions, improvements, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("base", help="baseline BENCH_workloads.json")
    ap.add_argument("new", help="candidate BENCH_workloads.json")
    ap.add_argument("--makespan-tol", type=float, default=0.02)
    ap.add_argument("--p99-tol", type=float, default=0.10)
    ap.add_argument("--ratio-tol", type=float, default=0.05)
    ap.add_argument("--advisory", action="store_true",
                    help="report regressions but exit 0 (CI perf diff)")
    args = ap.parse_args(argv)

    with open(args.base) as f:
        base = json.load(f)
    with open(args.new) as f:
        new = json.load(f)
    if base.get("schema_version") != new.get("schema_version"):
        print(f"note: schema_version {base.get('schema_version')} -> "
              f"{new.get('schema_version')} (columns may be partial)")

    regressions, improvements, notes = compare_docs(
        base, new, makespan_tol=args.makespan_tol, p99_tol=args.p99_tol,
        ratio_tol=args.ratio_tol)
    for n in notes:
        print(f"  note: {n}")
    for i in improvements:
        print(f"  improvement: {i}")
    for r in regressions:
        print(f"  REGRESSION: {r}")
    n_cells = len(new.get("runs", []))
    verdict = "REGRESSED" if regressions else "clean"
    print(f"bench compare: {verdict} — {len(regressions)} regressions, "
          f"{len(improvements)} improvements over {n_cells} cells"
          + (" [advisory]" if args.advisory and regressions else ""))
    return 1 if regressions and not args.advisory else 0


if __name__ == "__main__":
    sys.exit(main())
