"""Structural assertions for a sweep smoke run (CI's sweep-smoke step).

    python benchmarks/check_smoke.py BENCH_workloads.smoke.json [--expect-trace]

Carries everything the old Makefile inline one-liner checked (schema
version, check_ok across the grid, scoped API, remote-batch A/B, the
churned crash-recovery cell), the schema-v7 fused-engine cells (present,
bitwise-equal makespans against their batched twins, kernel_mode
recorded), the schema-v8 trace-driven traffic columns (kv_serving rows
present with offered vs completed request accounting sane and
per-request latency percentiles populated), and the schema-v6
observability columns: latency percentile keys present on every run
row, and — with --expect-trace, used when the smoke ran under
REPRO_TRACE=1 — at least one traced cell with events, plus a loadable
Chrome-trace JSON at the path the sweep doc names.  Exits nonzero with
the offending rows on any failure so the CI log shows *what* broke, not
just that it broke.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

LATENCY_KEYS = ("latency_p50", "latency_p95", "latency_p99",
                "latency_turns", "trace_events", "trace_dropped")
TRAFFIC_KEYS = ("offered_load", "completed", "zipf_s", "burstiness",
                "latency_source")


def check(doc: dict, *, expect_trace: bool, doc_dir: str = ".") -> list:
    """-> list of failure strings (empty = OK)."""
    fails = []
    if doc.get("schema_version") != 8:
        fails.append(f"schema_version {doc.get('schema_version')} != 8")
    runs = doc.get("runs", [])
    if not runs:
        fails.append("no runs")

    bad = [r for r in runs if not r.get("check_ok")
           and r.get("scenario") != "scope_only"]
    if bad:
        fails.append(f"check_ok failures: {bad}")
    if not all(r.get("api") == "scoped" for r in runs):
        fails.append("non-scoped api rows present")

    rb = [r for r in runs if r.get("remote_batch")]
    if not rb:
        fails.append("no remote-batch-capable cell in the grid")
    ab = doc.get("remote_batch_ab")
    if not ab or not all(r.get("check_ok") for r in ab):
        fails.append(f"remote_batch_ab missing or failed: {ab}")

    ch = [r for r in runs if r.get("churn_events")]
    if not ch:
        fails.append("no churned crash-recovery cell")
    elif not all(r.get("check_ok") and r.get("recovered", 0) > 0
                 and r.get("lost_updates") == 0 for r in ch):
        fails.append(f"churned cell failed: {ch}")

    # v6: every row carries the latency/trace columns (None/0 when the
    # tracer is off — presence is the schema contract, values are not)
    missing = [r for r in runs if any(k not in r for k in LATENCY_KEYS)]
    if missing:
        fails.append(f"rows missing v6 latency columns: {missing[:3]}")

    # v7: fused-engine grid rows, bitwise the batched schedule, with the
    # kernel dispatch mode recorded on every row and at top level
    fused = [r for r in runs if r.get("engine") == "fused"]
    if not fused:
        fails.append("no engine=fused cell in the grid (schema v7)")
    if doc.get("kernel_mode") not in ("pallas", "ref", "interpret"):
        fails.append(f"bad top-level kernel_mode: {doc.get('kernel_mode')}")
    no_mode = [r for r in runs if r.get("kernel_mode")
               not in ("pallas", "ref", "interpret")]
    if no_mode:
        fails.append(f"rows missing v7 kernel_mode column: {no_mode[:3]}")
    for f_ in fused:
        twin = next((r for r in runs if r.get("engine") == "batched"
                     and (r["workload"], r["scenario"], r["n_agents"])
                     == (f_["workload"], f_["scenario"], f_["n_agents"])),
                    None)
        if twin and twin["makespan"] != f_["makespan"]:
            fails.append(f"fused/batched makespan diverges: {f_} vs {twin}")

    # v8: every row carries the traffic columns (None on non-trace-driven
    # cells), and the trace-driven kv_serving cells account offered vs
    # completed requests with per-request latency percentiles populated
    no_traffic = [r for r in runs if any(k not in r for k in TRAFFIC_KEYS)]
    if no_traffic:
        fails.append(f"rows missing v8 traffic columns: {no_traffic[:3]}")
    kv = [r for r in runs if r.get("workload") == "kv_serving"]
    if not kv:
        fails.append("no kv_serving cell in the grid (schema v8)")
    for r in kv:
        ok_counts = (isinstance(r.get("offered_load"), int)
                     and isinstance(r.get("completed"), int)
                     and 0 < r["completed"] <= r["offered_load"])
        if not ok_counts:
            fails.append(f"kv_serving offered/completed insane: {r}")
        if r.get("latency_source") != "requests" \
                or not r.get("latency_turns") \
                or r.get("latency_p99") is None:
            fails.append(f"kv_serving row lacks request latency: {r}")
        # healthy non-churned cells must complete every offered request
        if ok_counts and not r.get("churn_events") and r.get("check_ok") \
                and r["completed"] != r["offered_load"]:
            fails.append(f"kv_serving dropped requests without churn: {r}")

    tr = doc.get("trace")
    if not isinstance(tr, dict) or "enabled" not in tr:
        fails.append(f"missing v6 top-level trace doc: {tr}")
    if "stragglers" not in doc:
        fails.append("missing v6 top-level stragglers list")

    if expect_trace:
        if not (tr and tr.get("enabled")):
            fails.append("--expect-trace but doc says tracing was off "
                         "(run the sweep under REPRO_TRACE=1)")
        traced = [r for r in runs if r.get("trace_events")]
        if not traced:
            fails.append("--expect-trace but no run row has trace_events > 0")
        else:
            with_lat = [r for r in traced if r.get("latency_p99") is not None
                        and r.get("latency_turns", 0) > 0]
            if not with_lat:
                fails.append(f"traced rows lack latency percentiles: "
                             f"{traced[:3]}")
        tf = tr.get("file") if tr else None
        if not tf:
            fails.append("--expect-trace but doc names no trace file")
        else:
            path = tf if os.path.isabs(tf) else os.path.join(doc_dir, tf)
            try:
                with open(path) as f:
                    tdoc = json.load(f)
                evs = tdoc.get("traceEvents")
                if not evs or not any(e.get("ph") == "X" for e in evs):
                    fails.append(f"{tf}: no duration events in traceEvents")
            except (OSError, ValueError) as e:
                fails.append(f"trace file {tf} unreadable: {e}")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("doc", help="BENCH_workloads.smoke.json from the sweep")
    ap.add_argument("--expect-trace", action="store_true",
                    help="require a traced cell + loadable Perfetto JSON "
                         "(smoke ran under REPRO_TRACE=1)")
    args = ap.parse_args(argv)

    with open(args.doc) as f:
        doc = json.load(f)
    fails = check(doc, expect_trace=args.expect_trace,
                  doc_dir=os.path.dirname(os.path.abspath(args.doc)))
    for msg in fails:
        print(f"  FAIL: {msg}")
    if fails:
        print(f"sweep smoke FAILED: {len(fails)} checks")
        return 1
    runs = doc["runs"]
    rb = [r for r in runs if r.get("remote_batch")]
    ch = [r for r in runs if r.get("churn_events")]
    traced = [r for r in runs if r.get("trace_events")]
    fused = [r for r in runs if r.get("engine") == "fused"]
    kv = [r for r in runs if r.get("workload") == "kv_serving"]
    served = sum(r.get("completed") or 0 for r in kv)
    print(f"sweep smoke OK: {len(runs)} cells, {len(rb)} remote-batch, "
          f"{len(ch)} churned, {len(traced)} traced, {len(fused)} fused, "
          f"{len(kv)} kv_serving ({served} requests served) "
          f"(kernel_mode={doc.get('kernel_mode')})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
