"""Analytic roofline table (all 32 single-pod cells) — the primary §Roofline
artifact; see repro/perf/roofline_model.py for why HLO cost_analysis alone
is insufficient on the CPU dry-run host."""
from __future__ import annotations

import json
import os

from repro.configs.base import SHAPES, applicable
from repro.models.registry import ARCH_IDS, get_config
from repro.perf.roofline_model import Plan, roofline


def rows(plan: Plan = None):
    plan = plan or Plan()
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if not applicable(cfg, s):
                continue
            out.append(roofline(cfg, s, plan))
    return out


def table(rs) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'bound':>11s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rs:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['t_compute_s']:10.4f} "
            f"{r['t_memory_s']:10.4f} {r['t_collective_s']:10.4f} "
            f"{r['bound']:>11s} {100*r['roofline_frac']:7.2f}")
    return "\n".join(lines)


def main():
    rs = rows()
    print(table(rs))
    os.makedirs("artifacts", exist_ok=True)
    json.dump(rs, open("artifacts/roofline_analytic.json", "w"), indent=1)


if __name__ == "__main__":
    main()
