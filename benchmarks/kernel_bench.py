"""Kernel micro-benchmarks: wall time of the jnp reference path on CPU (the
Pallas path is TPU-targeted; interpret mode timing is not meaningful), plus
derived bytes/flops so the table carries roofline context."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=10):
    fn(*args)
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def rows():
    rng = np.random.default_rng(0)
    out = []

    from repro.kernels.selective_flush.ref import selective_flush_ref
    bank = jnp.asarray(rng.normal(size=(4096, 512)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 4096, 128).astype(np.int32))
    us = _time(jax.jit(selective_flush_ref), bank, idx)
    out.append(("selective_flush_4096x512_d128", us,
                f"{128*512*4/us/1e3:.2f}GB/s"))

    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    x = jnp.asarray(rng.normal(size=(4096, 4096)).astype(np.float32))
    w = jnp.ones((4096,), jnp.float32)
    us = _time(jax.jit(rmsnorm_ref), x, w)
    out.append(("rmsnorm_4096x4096", us, f"{2*x.size*4/us/1e3:.2f}GB/s"))

    from repro.models.layers import blockwise_attention
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)).astype(np.float32))
    f = jax.jit(lambda a, b, c: blockwise_attention(a, b, c, block_k=256))
    us = _time(f, q, k, q[:, :2] * 0 + k)
    flops = 4 * 1 * 8 * 1024 * 1024 * 64 / 2
    out.append(("blockwise_attn_1x8x1024x64", us, f"{flops/us/1e6:.2f}GFLOP/s"))

    from repro.kernels.flash_decode.ref import decode_attention_ref
    qd = jnp.asarray(rng.normal(size=(4, 8, 64)).astype(np.float32))
    kd = jnp.asarray(rng.normal(size=(4, 2, 8192, 64)).astype(np.float32))
    kvl = jnp.full((4,), 8192, jnp.int32)
    us = _time(jax.jit(decode_attention_ref), qd, kd, kd, kvl)
    out.append(("decode_attn_4x8_kv8192", us,
                f"{2*kd.size*4/us/1e3:.2f}GB/s"))

    from repro.kernels.topk_router.ref import topk_router_ref
    lg = jnp.asarray(rng.normal(size=(8192, 256)).astype(np.float32))
    us = _time(jax.jit(lambda l: topk_router_ref(l, 8)), lg)
    out.append(("topk_router_8192x256_k8", us, ""))

    # fused-turn megakernel surfaces (DESIGN.md §12): the trip plan and the
    # packed-plane commit, jnp reference path, at the sweep's agent counts.
    # Both metadata layouts ride one process — plane_commit tells packed
    # (uint32) and boolean (REPRO_NO_PACK=1) planes apart by dtype.
    from repro.core import bitmask
    from repro.kernels.fused_turn.ref import plane_commit_ref, trip_plan_ref
    for n_wgs in (64, 256, 1024):
        clocks = jnp.asarray(rng.integers(0, 64, n_wgs).astype(np.float32))
        can_l = jnp.asarray(rng.random(n_wgs) < 0.6)
        can_r = jnp.asarray(rng.random(n_wgs) < 0.4)
        bound = jnp.ones((n_wgs,), jnp.float32)
        raddr = jnp.asarray(rng.integers(0, 64, n_wgs).astype(np.int32))
        us = _time(jax.jit(lambda c, l, r, bd, ra: trip_plan_ref(
            c, l, r, bd, ra, None)), clocks, can_l, can_r, bound, raddr)
        out.append((f"fused_trip_plan_n{n_wgs}", us,
                    f"{n_wgs*n_wgs/us:.0f}Mpair/s"))

        nb, W = 64, 128
        L = bitmask.n_lanes(W)
        wv = jnp.asarray(rng.integers(0, 2**32, (n_wgs, nb, L),
                                      dtype=np.uint64).astype(np.uint32))
        wd = jnp.zeros_like(wv)
        b = jnp.asarray(rng.integers(0, nb, n_wgs).astype(np.int32))
        o = jnp.asarray(rng.integers(0, W, n_wgs).astype(np.int32))
        sv = jnp.ones((n_wgs,), bool)
        us = _time(jax.jit(plane_commit_ref), wv, wd, b, o, sv, sv)
        out.append((f"plane_commit_packed_n{n_wgs}", us,
                    f"{n_wgs/us:.2f}Mlane/s"))
        wvb = bitmask.unpack(wv, W)
        us = _time(jax.jit(plane_commit_ref), wvb, jnp.zeros_like(wvb),
                   b, o, sv, sv)
        out.append((f"plane_commit_bool_n{n_wgs}", us,
                    f"{n_wgs/us:.2f}Mlane/s"))

    from repro.models.moe import moe_apply, moe_init
    from repro.models.registry import get_config
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    xm = jnp.asarray(rng.normal(size=(2048, cfg.d_model)).astype(np.float32))
    us = _time(jax.jit(lambda pp, xx: moe_apply(pp, cfg, xx)[0]), p, xm)
    out.append(("moe_dispatch_2048tok_4e", us, ""))
    return out


def main():
    from repro.kernels import common
    # mode is chosen once per process; an interpret-mode benchmark is a
    # user error (REPRO_KERNEL_MODE=interpret) and warns loudly
    print(f"# kernel_mode={common.note_benchmark('kernel_bench')}")
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
