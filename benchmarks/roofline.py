"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape), single-pod mesh:
  compute term    = HLO_FLOPs_per_dev / peak_FLOP/s        (cost_analysis)
  memory term     = HLO_bytes_per_dev / HBM_bw             (cost_analysis)
  collective term = collective_bytes_per_dev / link_bw     (HLO parse)
(cost_analysis / memory_analysis / as_text are all per-device after SPMD
partitioning — verified in tests/test_dryrun_units.py.)
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def load(art_dir: str = "artifacts/dryrun", mesh: str = "single",
         tag: str = ""):
    rows = []
    for f in sorted(glob.glob(os.path.join(art_dir, f"*_{mesh}{tag}.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        rows.append(analyse(r))
    return rows


def analyse(r: dict) -> dict:
    flops = r["cost"].get("flops", 0.0)
    bytes_acc = r["cost"].get("bytes accessed", 0.0)
    coll = sum(v["bytes"] for v in r.get("collectives", {}).values()
               if isinstance(v, dict))
    t_c = flops / PEAK_FLOPS
    t_m = bytes_acc / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    n_dev = r.get("n_devices", 256)
    useful = r["model_flops"] / (flops * n_dev) if flops else 0.0
    # roofline fraction: useful model FLOPs per chip over what the dominant
    # bound allows in the same wall-clock
    t_bound = max(terms.values()) or 1e-30
    frac = (r["model_flops"] / n_dev / PEAK_FLOPS) / t_bound
    return {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
            "bound": dom, "model_flops": r["model_flops"],
            "hlo_flops_per_dev": flops, "useful_flop_ratio": useful,
            "roofline_frac": frac,
            "temp_gib": r.get("memory", {}).get("temp_size_in_bytes", 0)
            / 2**30,
            "collectives": r.get("collectives", {})}


def table(rows) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'bound':>10s} {'useful':>7s} {'roofl%':>7s} "
           f"{'temp_GiB':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['t_compute_s']:10.4f} "
            f"{r['t_memory_s']:10.4f} {r['t_collective_s']:10.4f} "
            f"{r['bound']:>10s} {r['useful_flop_ratio']:7.3f} "
            f"{100*r['roofline_frac']:7.2f} {r['temp_gib']:9.2f}")
    return "\n".join(lines)


def main():
    rows = load()
    print(table(rows))
    out = "artifacts/roofline_single.json"
    os.makedirs("artifacts", exist_ok=True)
    json.dump(rows, open(out, "w"), indent=1)
    print(f"\nwrote {out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
