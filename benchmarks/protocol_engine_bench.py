"""Protocol-engine benchmark: serial vs batched work-steal engine.

Measures, per app x scenario x n_wgs, for each engine:
  * compile_s            first-call wall time (jit compile + first iteration)
  * steady_s_per_iter    mean wall time of subsequent simulator iterations
  * events_per_iter      scheduler turns executed per iteration
  * events_per_s         events_per_iter / steady_s_per_iter
and emits BENCH_protocol_engine.json, including batched-vs-serial speedups.

Seed-engine baseline: pass --seed-src <path-to-seed-checkout>/src (e.g. a
`git worktree add seed-tree <seed-commit>` of the pre-refactor engine) and
the same measurement runs against the old scan-based engine in a
subprocess; speedup_vs_seed fields are then filled in.  The JSON committed
with the refactor PR was produced this way against commit 9810f7e.

Usage:
  PYTHONPATH=src python benchmarks/protocol_engine_bench.py \
      [--apps pagerank] [--scenarios srsp rsp] [--sizes 16 64 256] \
      [--iters 4] [--seed-src seed-tree/src] [--out BENCH_protocol_engine.json]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp


# shape of one benchmark configuration, shared with the seed subprocess
def bench_config(n_wgs: int):
    n_chunks = max(2 * n_wgs, 64)
    graph_n = 32 * (n_chunks // 2)      # half-full queues: steals happen
    return n_chunks, graph_n


_MEASURE_SNIPPET = r"""
import json, sys, time
import numpy as np
import jax, jax.numpy as jnp
from repro.core.worksteal import WorkStealSim, WSConfig, SimState
from repro.data.graphs import collab_like

app, scenario, n_wgs, n_chunks, graph_n, iters, engine = (
    sys.argv[1], sys.argv[2], int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]), int(sys.argv[6]), sys.argv[7])

ws = WSConfig(n_wgs=n_wgs, chunk_cap=32, n_chunks_max=n_chunks)
g = collab_like(n=graph_n, m=4, seed=2)
sim = (WorkStealSim(ws, scenario) if engine == "seed"
       else WorkStealSim(ws, scenario, engine))
store = sim.make_store()
last_inv = jnp.zeros((ws.n_wgs,), jnp.float32)
frontier = np.arange(g.n, dtype=np.int32)

errors = 0
t0 = time.perf_counter()
store, last_inv, e, _ = sim.run_iteration(store, frontier, g.degrees, last_inv)
jax.block_until_ready(store.counters.cycles)
compile_s = time.perf_counter() - t0
errors += e

times = []
for _ in range(iters):
    t0 = time.perf_counter()
    store, last_inv, e, _ = sim.run_iteration(store, frontier, g.degrees,
                                              last_inv)
    jax.block_until_ready(store.counters.cycles)
    times.append(time.perf_counter() - t0)
    errors += e

# scheduler turns: every pop/steal turn is one acquire+release pair; the
# per-iteration batched enqueue contributes one pair per work-group, which
# is setup, not a round-loop turn — subtract it
c = store.counters
sync_pairs = float(c.local_syncs + c.remote_syncs + c.global_syncs) / 2.0
events = sync_pairs - n_wgs * (iters + 1)
steady = float(np.mean(times))
print(json.dumps({
    "app": app, "scenario": scenario, "n_wgs": n_wgs, "engine": engine,
    "n_chunks": n_chunks, "graph_n": graph_n, "iters_timed": iters,
    "compile_s": round(compile_s, 4),
    "steady_s_per_iter": round(steady, 5),
    "events_total": events,
    "events_per_iter": round(events / (iters + 1), 1),
    "events_per_s": round(events / (iters + 1) / steady, 1),
    "proc_errors": errors,
    "makespan": float(jnp.max(c.cycles)),
}))
"""


def measure(app, scenario, n_wgs, iters, engine, seed_src=None):
    """Run one config in a subprocess (isolates jit caches and lets the
    seed engine import from an old checkout)."""
    n_chunks, graph_n = bench_config(n_wgs)
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = seed_src if engine == "seed" else os.path.join(root, "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    out = subprocess.run(
        [sys.executable, "-c", _MEASURE_SNIPPET, app, scenario, str(n_wgs),
         str(n_chunks), str(graph_n), str(iters), engine],
        capture_output=True, text=True, env=env)
    if out.returncode != 0:
        print(out.stderr[-2000:], file=sys.stderr)
        raise RuntimeError(f"bench subprocess failed: {app}/{scenario}/"
                           f"{n_wgs}/{engine}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--apps", nargs="+", default=["pagerank"])
    ap.add_argument("--scenarios", nargs="+", default=["srsp", "rsp"])
    ap.add_argument("--sizes", nargs="+", type=int, default=[16, 64, 256])
    ap.add_argument("--engines", nargs="+", default=["batched", "serial"])
    ap.add_argument("--iters", type=int, default=4,
                    help="steady-state iterations per config (halved for "
                         "n_wgs >= 256)")
    ap.add_argument("--seed-src", default=None,
                    help="path to a pre-refactor checkout's src/ to measure "
                         "the seed engine baseline live")
    ap.add_argument("--serial-max-wgs", type=int, default=128,
                    help="skip serial/seed engines above this n_wgs (the "
                         "scan-serialized engines take minutes per iteration "
                         "there — the scaling wall this bench documents)")
    ap.add_argument("--out", default="BENCH_protocol_engine.json")
    args = ap.parse_args()

    engines = list(args.engines)
    if args.seed_src:
        engines.append("seed")

    runs = []
    for app in args.apps:
        for scen in args.scenarios:
            for n_wgs in args.sizes:
                iters = max(1, args.iters // 2) if n_wgs >= 256 else args.iters
                for engine in engines:
                    if engine != "batched" and n_wgs > args.serial_max_wgs:
                        print(f"{app}/{scen}/n_wgs={n_wgs}/{engine}: skipped "
                              f"(--serial-max-wgs {args.serial_max_wgs}; "
                              f"measured 43.8 s/iter for serial at 256 — "
                              f"beyond the old engine's reach)", flush=True)
                        continue
                    t0 = time.perf_counter()
                    rec = measure(app, scen, n_wgs, iters, engine,
                                  args.seed_src)
                    rec["bench_wall_s"] = round(time.perf_counter() - t0, 2)
                    runs.append(rec)
                    print(f"{app}/{scen}/n_wgs={n_wgs}/{engine}: "
                          f"compile={rec['compile_s']:.2f}s "
                          f"steady={rec['steady_s_per_iter'] * 1e3:.1f}ms/iter "
                          f"events/s={rec['events_per_s']:.0f} "
                          f"errors={rec['proc_errors']}", flush=True)

    def find(app, scen, n, engine):
        for r in runs:
            if (r["app"], r["scenario"], r["n_wgs"], r["engine"]) == \
                    (app, scen, n, engine):
                return r
        return None

    speedups = {}
    for app in args.apps:
        for scen in args.scenarios:
            for n_wgs in args.sizes:
                bat = find(app, scen, n_wgs, "batched")
                ser = find(app, scen, n_wgs, "serial")
                seed = find(app, scen, n_wgs, "seed")
                if not bat:
                    continue
                entry = {}
                if ser:
                    entry["batched_vs_serial"] = round(
                        ser["steady_s_per_iter"] / bat["steady_s_per_iter"], 2)
                if seed:
                    entry["batched_vs_seed"] = round(
                        seed["steady_s_per_iter"] / bat["steady_s_per_iter"], 2)
                    entry["serial_vs_seed"] = round(
                        seed["steady_s_per_iter"] / ser["steady_s_per_iter"], 2) \
                        if ser else None
                speedups[f"{app}/{scen}/n_wgs={n_wgs}"] = entry

    doc = {
        "bench": "protocol_engine",
        "metric_note": "speedups compare steady-state wall-clock per "
                       "simulator iteration (run_app minus one-time jit "
                       "compile); compile_s is reported separately per run",
        "backend": jax.default_backend(),
        "config": {"apps": args.apps, "scenarios": args.scenarios,
                   "sizes": args.sizes, "iters": args.iters,
                   "seed_src": args.seed_src},
        "runs": runs,
        "speedups": speedups,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"wrote {args.out}")
    for k, v in speedups.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
